//! Mapped LUT netlists and pipelined circuits.
//!
//! After technology mapping, a neuron/layer/network is a DAG of k-input
//! LUTs ([`LutNetlist`]). The hardware realization the paper reports is a
//! *pipelined* version: register boundaries between network layers (and
//! after retiming, wherever the retimer moved them). [`PipelinedCircuit`]
//! couples a flattened netlist with a stage assignment and provides the
//! LUT/FF/depth statistics that Table I quotes.

use crate::logic::truthtable::TruthTable;

/// Reference to a signal in a [`LutNetlist`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Sig {
    /// Constant 0 or 1.
    Const(bool),
    /// Primary input by index.
    Input(u32),
    /// Output of LUT `i`.
    Lut(u32),
}

impl Sig {
    /// Dense integer encoding of a signal, shared by the compiled simulator
    /// ([`crate::logic::sim`]) and the circuit artifact format
    /// ([`crate::flow::artifact`]): `0` = const 0, `1` = const 1, `2 + i` =
    /// input `i`, `2 + num_inputs + j` = LUT `j`.
    #[inline]
    pub fn to_code(self, num_inputs: usize) -> u32 {
        match self {
            Sig::Const(false) => 0,
            Sig::Const(true) => 1,
            Sig::Input(i) => 2 + i,
            Sig::Lut(j) => 2 + num_inputs as u32 + j,
        }
    }

    /// Inverse of [`Sig::to_code`]. Any `code ≥ 2 + num_inputs` decodes to a
    /// LUT reference; range-check against the netlist before use.
    #[inline]
    pub fn from_code(code: u32, num_inputs: usize) -> Sig {
        match code {
            0 => Sig::Const(false),
            1 => Sig::Const(true),
            c if (c as usize) < 2 + num_inputs => Sig::Input(c - 2),
            c => Sig::Lut(c - 2 - num_inputs as u32),
        }
    }
}

/// A k-input lookup table node.
#[derive(Clone, Debug)]
pub struct Lut {
    /// Input signals (order matches truth-table variable order).
    pub inputs: Vec<Sig>,
    /// Function over `inputs.len()` variables.
    pub table: TruthTable,
}

impl Lut {
    /// Number of inputs.
    pub fn arity(&self) -> usize {
        self.inputs.len()
    }
}

/// A combinational network of LUTs in topological order (a LUT's inputs may
/// only reference primary inputs or earlier LUTs).
#[derive(Clone, Debug, Default)]
pub struct LutNetlist {
    /// Number of primary inputs.
    pub num_inputs: usize,
    /// LUT nodes, topologically sorted.
    pub luts: Vec<Lut>,
    /// Primary outputs: signal plus inversion flag.
    pub outputs: Vec<(Sig, bool)>,
}

impl LutNetlist {
    /// Empty netlist with `num_inputs` primary inputs.
    pub fn new(num_inputs: usize) -> Self {
        LutNetlist { num_inputs, luts: Vec::new(), outputs: Vec::new() }
    }

    /// Append a LUT; returns its signal. Panics if inputs are not yet
    /// defined (enforces topological order).
    pub fn add_lut(&mut self, inputs: Vec<Sig>, table: TruthTable) -> Sig {
        assert_eq!(table.nvars(), inputs.len());
        let idx = self.luts.len() as u32;
        for s in &inputs {
            match s {
                Sig::Lut(i) => assert!(*i < idx, "inputs must precede the LUT"),
                Sig::Input(i) => assert!((*i as usize) < self.num_inputs),
                Sig::Const(_) => {}
            }
        }
        self.luts.push(Lut { inputs, table });
        Sig::Lut(idx)
    }

    /// Register a primary output.
    pub fn add_output(&mut self, sig: Sig, inverted: bool) {
        self.outputs.push((sig, inverted));
    }

    /// Number of LUTs.
    pub fn num_luts(&self) -> usize {
        self.luts.len()
    }

    /// Maximum LUT arity.
    pub fn max_arity(&self) -> usize {
        self.luts.iter().map(|l| l.arity()).max().unwrap_or(0)
    }

    /// Logic level of each LUT (inputs at level 0; a LUT is 1 + max of its
    /// input levels).
    pub fn levels(&self) -> Vec<u32> {
        let mut lv = vec![0u32; self.luts.len()];
        for (i, lut) in self.luts.iter().enumerate() {
            let m = lut
                .inputs
                .iter()
                .map(|s| match s {
                    Sig::Lut(j) => lv[*j as usize],
                    _ => 0,
                })
                .max()
                .unwrap_or(0);
            lv[i] = m + 1;
        }
        lv
    }

    /// Depth (max level over outputs).
    pub fn depth(&self) -> u32 {
        let lv = self.levels();
        self.outputs
            .iter()
            .map(|(s, _)| match s {
                Sig::Lut(i) => lv[*i as usize],
                _ => 0,
            })
            .max()
            .unwrap_or(0)
    }

    /// 64-way bit-parallel evaluation: `inputs[i]` is a word of 64 samples
    /// for primary input `i`; returns a word per output.
    pub fn simulate_words(&self, inputs: &[u64]) -> Vec<u64> {
        assert_eq!(inputs.len(), self.num_inputs);
        let mut val = vec![0u64; self.luts.len()];
        let read = |val: &[u64], s: &Sig| -> u64 {
            match s {
                Sig::Const(false) => 0,
                Sig::Const(true) => !0u64,
                Sig::Input(i) => inputs[*i as usize],
                Sig::Lut(i) => val[*i as usize],
            }
        };
        for (i, lut) in self.luts.iter().enumerate() {
            let in_words: Vec<u64> = lut.inputs.iter().map(|s| read(&val, s)).collect();
            val[i] = eval_lut_words(&lut.table, &in_words);
        }
        self.outputs
            .iter()
            .map(|(s, inv)| read(&val, s) ^ if *inv { !0u64 } else { 0 })
            .collect()
    }

    /// Evaluate one assignment (bit `i` = primary input `i`).
    pub fn eval(&self, input_bits: u64) -> Vec<bool> {
        let words: Vec<u64> = (0..self.num_inputs)
            .map(|i| if (input_bits >> i) & 1 == 1 { !0u64 } else { 0 })
            .collect();
        self.simulate_words(&words).iter().map(|&w| w & 1 == 1).collect()
    }
}

/// Evaluate a LUT's table across 64 lanes: classic "truth-table gather" via
/// binary Shannon expansion over the input words (k table lookups become k
/// mux levels of word ops — branch-free and cache-friendly).
#[inline]
pub fn eval_lut_words(table: &TruthTable, in_words: &[u64]) -> u64 {
    debug_assert_eq!(table.nvars(), in_words.len());
    // Start from the table bits replicated per lane via recursion:
    // out = mux(in[k-1], hi_half, lo_half) applied word-wise.
    fn rec(table: &TruthTable, in_words: &[u64], lo: u64, span: usize, k: usize) -> u64 {
        if k == 0 {
            return if table.eval(lo) { !0u64 } else { 0 };
        }
        let half = span / 2;
        let w0 = rec(table, in_words, lo, half, k - 1);
        let w1 = rec(table, in_words, lo + half as u64, half, k - 1);
        let sel = in_words[k - 1];
        (sel & w1) | (!sel & w0)
    }
    let k = table.nvars();
    rec(table, in_words, 0, 1usize << k, k)
}

/// A pipelined circuit: a flattened netlist plus a register-stage
/// assignment. LUT `i` executes in stage `stage_of_lut[i] ∈ [0, num_stages)`;
/// registers sit at every stage boundary, at the primary inputs, and at the
/// primary outputs (the convention LogicNets and NullaNet Tiny both use for
/// their fmax reports).
#[derive(Clone, Debug)]
pub struct PipelinedCircuit {
    /// The combinational logic.
    pub netlist: LutNetlist,
    /// Stage index of every LUT (monotone non-decreasing along edges).
    pub stage_of_lut: Vec<u32>,
    /// Number of pipeline stages.
    pub num_stages: u32,
}

/// Hardware statistics (the Table I columns).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CircuitStats {
    /// Total LUT count.
    pub luts: usize,
    /// Total flip-flop count (input regs + inter-stage + output regs).
    pub ffs: usize,
    /// Critical combinational depth between any two register boundaries.
    pub max_stage_depth: u32,
    /// Pipeline latency in cycles (= num_stages; data is registered at
    /// every boundary).
    pub latency_cycles: u32,
}

impl PipelinedCircuit {
    /// Single-stage (purely combinational between I/O registers) wrapper.
    pub fn single_stage(netlist: LutNetlist) -> Self {
        let n = netlist.luts.len();
        PipelinedCircuit { netlist, stage_of_lut: vec![0; n], num_stages: 1 }
    }

    /// Validate the stage assignment: every edge must go from an earlier or
    /// equal stage, and stages must be in range.
    pub fn check_stages(&self) -> Result<(), String> {
        if self.stage_of_lut.len() != self.netlist.luts.len() {
            return Err("stage vector length mismatch".into());
        }
        for (i, lut) in self.netlist.luts.iter().enumerate() {
            let si = self.stage_of_lut[i];
            if si >= self.num_stages {
                return Err(format!("LUT {i} stage {si} out of range"));
            }
            for s in &lut.inputs {
                if let Sig::Lut(j) = s {
                    let sj = self.stage_of_lut[*j as usize];
                    if sj > si {
                        return Err(format!("edge {j}->{i} goes backward ({sj}>{si})"));
                    }
                }
            }
        }
        Ok(())
    }

    /// Combinational depth of every stage (unit delay per LUT).
    pub fn stage_depths(&self) -> Vec<u32> {
        let mut depth = vec![0u32; self.netlist.luts.len()];
        let mut per_stage = vec![0u32; self.num_stages as usize];
        for (i, lut) in self.netlist.luts.iter().enumerate() {
            let si = self.stage_of_lut[i];
            let m = lut
                .inputs
                .iter()
                .map(|s| match s {
                    Sig::Lut(j) if self.stage_of_lut[*j as usize] == si => {
                        depth[*j as usize]
                    }
                    _ => 0, // registered at the stage boundary
                })
                .max()
                .unwrap_or(0);
            depth[i] = m + 1;
            per_stage[si as usize] = per_stage[si as usize].max(depth[i]);
        }
        per_stage
    }

    /// Count flip-flops: input registers, plus every signal crossing each
    /// stage boundary (shift-register semantics for multi-stage crossings),
    /// plus output registers.
    pub fn count_ffs(&self) -> usize {
        let s = self.num_stages;
        // last stage in which each signal is consumed
        let mut ffs = 0usize;

        // Input registers: every primary input is registered once at entry.
        ffs += self.netlist.num_inputs;

        // A signal produced at stage p (LUT) or -1 (input) consumed at
        // stage c needs one FF at every boundary strictly between p and c.
        // Boundaries: after stage k for k in 0..s-1 (the output boundary is
        // counted via output registers below).
        let prod_stage = |sig: &Sig| -> i64 {
            match sig {
                Sig::Lut(j) => self.stage_of_lut[*j as usize] as i64,
                _ => -1, // inputs are available (registered) at stage 0
            }
        };
        // For each signal, find the max stage where it is consumed; FFs
        // needed = boundaries crossed = max(0, last_use - prod).
        use std::collections::HashMap;
        let mut last_use: HashMap<Sig, i64> = HashMap::new();
        for (i, lut) in self.netlist.luts.iter().enumerate() {
            let si = self.stage_of_lut[i] as i64;
            for sig in &lut.inputs {
                if matches!(sig, Sig::Const(_)) {
                    continue;
                }
                let e = last_use.entry(*sig).or_insert(i64::MIN);
                *e = (*e).max(si);
            }
        }
        for (sig, _) in &self.netlist.outputs {
            if matches!(sig, Sig::Const(_)) {
                continue;
            }
            let e = last_use.entry(*sig).or_insert(i64::MIN);
            *e = (*e).max(s as i64 - 1);
        }
        for (sig, last) in &last_use {
            let p = prod_stage(sig);
            if *last > p {
                ffs += (*last - p.max(0)) as usize;
                // inputs: produced "at boundary 0" — crossing from stage 0
                // onward; p = -1 treated as 0 since the input reg at entry
                // is already counted.
            }
        }
        // Output registers.
        ffs += self.netlist.outputs.len();
        ffs
    }

    /// Full statistics.
    pub fn stats(&self) -> CircuitStats {
        CircuitStats {
            luts: self.netlist.num_luts(),
            ffs: self.count_ffs(),
            max_stage_depth: self.stage_depths().iter().copied().max().unwrap_or(0),
            latency_cycles: self.num_stages,
        }
    }

    /// Functional evaluation ignores pipelining (registers only delay).
    pub fn eval(&self, input_bits: u64) -> Vec<bool> {
        self.netlist.eval(input_bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    fn xor_tt() -> TruthTable {
        TruthTable::from_fn(2, |m| (m.count_ones() & 1) == 1)
    }

    #[test]
    fn build_and_eval_xor_chain() {
        // out = in0 ^ in1 ^ in2 via two 2-input LUTs.
        let mut n = LutNetlist::new(3);
        let a = n.add_lut(vec![Sig::Input(0), Sig::Input(1)], xor_tt());
        let b = n.add_lut(vec![a, Sig::Input(2)], xor_tt());
        n.add_output(b, false);
        for m in 0..8u64 {
            let want = (m.count_ones() & 1) == 1;
            assert_eq!(n.eval(m)[0], want, "m={m}");
        }
        assert_eq!(n.depth(), 2);
    }

    #[test]
    fn inverted_output() {
        let mut n = LutNetlist::new(2);
        let a = n.add_lut(vec![Sig::Input(0), Sig::Input(1)], xor_tt());
        n.add_output(a, true); // XNOR
        for m in 0..4u64 {
            assert_eq!(n.eval(m)[0], (m.count_ones() & 1) == 0);
        }
    }

    #[test]
    fn const_and_input_outputs() {
        let mut n = LutNetlist::new(2);
        n.add_output(Sig::Const(true), false);
        n.add_output(Sig::Input(1), true);
        assert_eq!(n.eval(0b10), vec![true, false]);
        assert_eq!(n.eval(0b00), vec![true, true]);
    }

    #[test]
    fn eval_lut_words_matches_scalar() {
        let mut rng = Xoshiro256::new(0x1111);
        for k in 0..=6usize {
            let tt = TruthTable::from_fn(k, |_| rng.bernoulli(0.5));
            let words: Vec<u64> = (0..k).map(|_| rng.next_u64()).collect();
            let out = eval_lut_words(&tt, &words);
            for lane in 0..64 {
                let addr: u64 = (0..k).map(|i| ((words[i] >> lane) & 1) << i).sum();
                assert_eq!((out >> lane) & 1 == 1, tt.eval(addr), "k={k} lane={lane}");
            }
        }
    }

    #[test]
    fn simulate_words_matches_eval() {
        let mut rng = Xoshiro256::new(0x2222);
        let mut n = LutNetlist::new(4);
        let t1 = TruthTable::from_fn(3, |m| m == 3 || m == 5);
        let a = n.add_lut(vec![Sig::Input(0), Sig::Input(1), Sig::Input(2)], t1);
        let t2 = TruthTable::from_fn(2, |m| m != 0);
        let b = n.add_lut(vec![a, Sig::Input(3)], t2);
        n.add_output(b, false);
        n.add_output(a, true);
        let words: Vec<u64> = (0..4).map(|_| rng.next_u64()).collect();
        let outs = n.simulate_words(&words);
        for lane in 0..64 {
            let bits: u64 = (0..4).map(|i| ((words[i] >> lane) & 1) << i).sum();
            let e = n.eval(bits);
            assert_eq!((outs[0] >> lane) & 1 == 1, e[0]);
            assert_eq!((outs[1] >> lane) & 1 == 1, e[1]);
        }
    }

    #[test]
    fn sig_code_roundtrip() {
        let num_inputs = 5usize;
        let sigs = [
            Sig::Const(false),
            Sig::Const(true),
            Sig::Input(0),
            Sig::Input(4),
            Sig::Lut(0),
            Sig::Lut(17),
        ];
        for s in sigs {
            assert_eq!(Sig::from_code(s.to_code(num_inputs), num_inputs), s);
        }
        assert_eq!(Sig::Input(0).to_code(num_inputs), 2);
        assert_eq!(Sig::Lut(0).to_code(num_inputs), 2 + num_inputs as u32);
    }

    #[test]
    fn stage_check_catches_backward_edges() {
        let mut n = LutNetlist::new(1);
        let a = n.add_lut(vec![Sig::Input(0)], TruthTable::from_fn(1, |m| m == 0));
        let b = n.add_lut(vec![a], TruthTable::from_fn(1, |m| m == 1));
        n.add_output(b, false);
        let good = PipelinedCircuit {
            netlist: n.clone(),
            stage_of_lut: vec![0, 1],
            num_stages: 2,
        };
        assert!(good.check_stages().is_ok());
        let bad = PipelinedCircuit {
            netlist: n,
            stage_of_lut: vec![1, 0],
            num_stages: 2,
        };
        assert!(bad.check_stages().is_err());
    }

    #[test]
    fn stage_depths_and_ffs() {
        // 3 LUTs in a chain over 2 stages: [L0, L1 | L2]
        let mut n = LutNetlist::new(2);
        let inv = TruthTable::from_fn(1, |m| m == 0);
        let a = n.add_lut(vec![Sig::Input(0)], inv.clone());
        let b = n.add_lut(vec![a], inv.clone());
        let c = n.add_lut(vec![b], inv.clone());
        n.add_output(c, false);
        let p = PipelinedCircuit {
            netlist: n,
            stage_of_lut: vec![0, 0, 1],
            num_stages: 2,
        };
        p.check_stages().unwrap();
        assert_eq!(p.stage_depths(), vec![2, 1]);
        // FFs: 2 input regs + 1 crossing (b from stage0→1) + 1 output reg.
        assert_eq!(p.count_ffs(), 2 + 1 + 1);
        let st = p.stats();
        assert_eq!(st.luts, 3);
        assert_eq!(st.max_stage_depth, 2);
        assert_eq!(st.latency_cycles, 2);
    }

    #[test]
    fn multi_stage_crossing_counts_shift_register() {
        // Signal produced in stage 0, consumed in stage 2 → 2 FFs.
        let mut n = LutNetlist::new(1);
        let inv = TruthTable::from_fn(1, |m| m == 0);
        let a = n.add_lut(vec![Sig::Input(0)], inv.clone());
        let b = n.add_lut(vec![Sig::Input(0)], inv.clone());
        let c = n.add_lut(vec![a, b], xor_tt());
        n.add_output(c, false);
        let p = PipelinedCircuit {
            netlist: n,
            stage_of_lut: vec![0, 2, 2],
            num_stages: 3,
        };
        p.check_stages().unwrap();
        // input reg (1) + a crosses 0→2 (2 FFs) + input0 consumed at stage 2
        // crossing from 0→2 (2 FFs) + output reg (1)
        assert_eq!(p.count_ffs(), 1 + 2 + 2 + 1);
    }
}
