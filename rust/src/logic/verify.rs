//! Equivalence checking between synthesis stages.
//!
//! The flow verifies every transformation: neuron truth table ≡ minimized
//! SOP ≡ AIG cone ≡ mapped LUT netlist ≡ retimed circuit. Small cones are
//! checked *exhaustively* (the paper's functions are ≤ γ·β ≤ 16 inputs);
//! whole networks are checked by dense directed + random sampling against
//! the exact integer NN evaluation.

use crate::logic::check::CheckError;
use crate::logic::netlist::LutNetlist;
use crate::logic::truthtable::TruthTable;
use crate::util::prng::Xoshiro256;

/// Result of an equivalence check.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EquivResult {
    /// Functions agree on every checked assignment.
    Equivalent,
    /// First mismatching assignment and the (got, want) output vectors.
    Mismatch {
        /// Index of the failing assignment in enumeration/sample order —
        /// the exact case to replay.
        sample: usize,
        /// The failing assignment itself (first 64 inputs, bit `i` = input
        /// `i`).
        input_bits: u64,
        /// Outputs the netlist produced.
        got: Vec<bool>,
        /// Outputs the reference produced.
        want: Vec<bool>,
    },
}

impl EquivResult {
    /// True when equivalent.
    pub fn is_equivalent(&self) -> bool {
        matches!(self, EquivResult::Equivalent)
    }
}

/// Exhaustively compare a netlist against per-output truth tables
/// (netlist inputs = table variables; ≤ 24 inputs).
pub fn exhaustive_netlist_vs_tables(nl: &LutNetlist, tables: &[TruthTable]) -> EquivResult {
    assert!(nl.num_inputs <= 24, "exhaustive check limited to 24 inputs");
    assert_eq!(nl.outputs.len(), tables.len());
    for t in tables {
        assert_eq!(t.nvars(), nl.num_inputs);
    }
    let sim = crate::logic::sim::CompiledNetlist::compile(nl);
    let mut scratch = sim.make_scratch();
    let mut in_words = vec![0u64; nl.num_inputs];
    let mut out_words = vec![0u64; nl.outputs.len()];
    let total = 1u64 << nl.num_inputs;
    let mut base = 0u64;
    while base < total {
        let lanes = (total - base).min(64) as usize;
        for (i, w) in in_words.iter_mut().enumerate() {
            *w = 0;
            for lane in 0..lanes {
                if ((base + lane as u64) >> i) & 1 == 1 {
                    *w |= 1 << lane;
                }
            }
        }
        sim.run_words(&mut scratch, &in_words, &mut out_words);
        for lane in 0..lanes {
            let m = base + lane as u64;
            for (j, t) in tables.iter().enumerate() {
                let got = (out_words[j] >> lane) & 1 == 1;
                let want = t.eval(m);
                if got != want {
                    let got_v: Vec<bool> = out_words
                        .iter()
                        .map(|w| (w >> lane) & 1 == 1)
                        .collect();
                    let want_v: Vec<bool> = tables.iter().map(|t| t.eval(m)).collect();
                    return EquivResult::Mismatch {
                        sample: m as usize,
                        input_bits: m,
                        got: got_v,
                        want: want_v,
                    };
                }
            }
        }
        base += lanes as u64;
    }
    EquivResult::Equivalent
}

/// Input-count ceiling for exhaustive enumeration (2^24 assignments).
pub const EXHAUSTIVE_LIMIT: usize = 24;

/// Exhaustively compare two netlists. Mismatched I/O signatures and
/// netlists too wide to enumerate are typed errors, not panics — callers
/// (the CLI, the property suite) feed this arbitrary artifact pairs.
pub fn exhaustive_netlists(a: &LutNetlist, b: &LutNetlist) -> Result<EquivResult, CheckError> {
    if a.num_inputs != b.num_inputs || a.outputs.len() != b.outputs.len() {
        return Err(CheckError::SignatureMismatch {
            inputs: (a.num_inputs, b.num_inputs),
            outputs: (a.outputs.len(), b.outputs.len()),
        });
    }
    if a.num_inputs > EXHAUSTIVE_LIMIT {
        return Err(CheckError::TooManyInputs {
            num_inputs: a.num_inputs,
            limit: EXHAUSTIVE_LIMIT,
        });
    }
    for m in 0..1u64 << a.num_inputs {
        let ga = a.eval(m);
        let gb = b.eval(m);
        if ga != gb {
            return Ok(EquivResult::Mismatch {
                sample: m as usize,
                input_bits: m,
                got: ga,
                want: gb,
            });
        }
    }
    Ok(EquivResult::Equivalent)
}

/// Compare a netlist against an arbitrary oracle on `samples` random
/// assignments (for networks too wide to enumerate).
pub fn sampled_netlist_vs_fn(
    nl: &LutNetlist,
    oracle: impl Fn(&[bool]) -> Vec<bool>,
    samples: usize,
    seed: u64,
) -> EquivResult {
    let mut rng = Xoshiro256::new(seed);
    let sim = crate::logic::sim::CompiledNetlist::compile(nl);
    let batch: Vec<Vec<bool>> = (0..samples)
        .map(|_| (0..nl.num_inputs).map(|_| rng.bernoulli(0.5)).collect())
        .collect();
    let got = sim.run_batch(&batch);
    for (sample, (s, g)) in batch.iter().zip(&got).enumerate() {
        let want = oracle(s);
        if *g != want {
            let bits: u64 = s
                .iter()
                .take(64)
                .enumerate()
                .map(|(i, &b)| if b { 1u64 << i } else { 0 })
                .sum();
            return EquivResult::Mismatch { sample, input_bits: bits, got: g.clone(), want };
        }
    }
    EquivResult::Equivalent
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::netlist::Sig;

    fn xor_tt() -> TruthTable {
        TruthTable::from_fn(2, |m| (m.count_ones() & 1) == 1)
    }

    #[test]
    fn exhaustive_accepts_correct_netlist() {
        let mut nl = LutNetlist::new(3);
        let a = nl.add_lut(vec![Sig::Input(0), Sig::Input(1)], xor_tt());
        let b = nl.add_lut(vec![a, Sig::Input(2)], xor_tt());
        nl.add_output(b, false);
        let want = TruthTable::from_fn(3, |m| (m.count_ones() & 1) == 1);
        assert!(exhaustive_netlist_vs_tables(&nl, &[want]).is_equivalent());
    }

    #[test]
    fn exhaustive_finds_mismatch() {
        let mut nl = LutNetlist::new(2);
        let a = nl.add_lut(vec![Sig::Input(0), Sig::Input(1)], xor_tt());
        nl.add_output(a, false);
        let wrong = TruthTable::from_fn(2, |m| m == 3); // AND, not XOR
        match exhaustive_netlist_vs_tables(&nl, &[wrong]) {
            EquivResult::Mismatch { input_bits, .. } => {
                // first mismatch is m=1 (xor=1, and=0)
                assert_eq!(input_bits, 1);
            }
            _ => panic!("must detect mismatch"),
        }
    }

    #[test]
    fn netlist_vs_netlist() {
        let mut a = LutNetlist::new(2);
        let x = a.add_lut(vec![Sig::Input(0), Sig::Input(1)], xor_tt());
        a.add_output(x, false);
        // same function, built differently (xnor then inverted output)
        let mut b = LutNetlist::new(2);
        let xn = b.add_lut(
            vec![Sig::Input(0), Sig::Input(1)],
            TruthTable::from_fn(2, |m| (m.count_ones() & 1) == 0),
        );
        b.add_output(xn, true);
        assert!(exhaustive_netlists(&a, &b).unwrap().is_equivalent());
    }

    #[test]
    fn mismatched_signatures_are_typed_errors_not_panics() {
        let a = LutNetlist::new(2);
        let b = LutNetlist::new(3);
        assert!(matches!(
            exhaustive_netlists(&a, &b),
            Err(CheckError::SignatureMismatch { inputs: (2, 3), .. })
        ));
        let mut c = LutNetlist::new(2);
        c.add_output(Sig::Input(0), false);
        let d = LutNetlist::new(2);
        assert!(matches!(
            exhaustive_netlists(&c, &d),
            Err(CheckError::SignatureMismatch { outputs: (1, 0), .. })
        ));
    }

    #[test]
    fn too_wide_for_enumeration_is_a_typed_error() {
        let a = LutNetlist::new(30);
        let b = LutNetlist::new(30);
        assert!(matches!(
            exhaustive_netlists(&a, &b),
            Err(CheckError::TooManyInputs { num_inputs: 30, limit: EXHAUSTIVE_LIMIT })
        ));
    }

    #[test]
    fn sampled_check_wide_network() {
        // 40-input parity via LUT tree — too wide to enumerate; sample.
        let mut nl = LutNetlist::new(40);
        let mut sigs: Vec<Sig> = (0..40).map(Sig::Input).collect();
        while sigs.len() > 1 {
            let mut next = Vec::new();
            for pair in sigs.chunks(2) {
                if pair.len() == 2 {
                    next.push(nl.add_lut(vec![pair[0], pair[1]], xor_tt()));
                } else {
                    next.push(pair[0]);
                }
            }
            sigs = next;
        }
        nl.add_output(sigs[0], false);
        let r = sampled_netlist_vs_fn(
            &nl,
            |bits| vec![bits.iter().filter(|&&b| b).count() % 2 == 1],
            500,
            42,
        );
        assert!(r.is_equivalent());
        // and the check itself can fail:
        let r2 = sampled_netlist_vs_fn(
            &nl,
            |bits| vec![bits.iter().filter(|&&b| b).count() % 2 == 0],
            500,
            42,
        );
        // The inverted oracle disagrees everywhere, so the reported failing
        // sample must be the very first one.
        match r2 {
            EquivResult::Mismatch { sample, .. } => assert_eq!(sample, 0),
            EquivResult::Equivalent => panic!("inverted oracle must mismatch"),
        }
    }
}
