//! Dense truth tables and the Minato–Morreale irredundant SOP generator.
//!
//! NullaNet's input enumeration produces, for every neuron output bit, a
//! *dense* truth table over γ·β ≤ ~16 inputs. This module stores those
//! tables as packed bit vectors, provides cofactoring/composition, and
//! converts ON/DC sets into a compact [`Cover`] via the Minato–Morreale
//! ISOP recursion — the seed cover handed to ESPRESSO-II (starting ESPRESSO
//! from raw minterms would be quadratically slower; starting from an ISOP is
//! the standard production trick).

use crate::logic::cube::{Cover, Cube, Pol};
use crate::util::bitvec::BitVec;

/// A completely-specified Boolean function over `nvars` inputs, stored as a
/// packed table of 2^nvars bits (bit `i` = f(i), input bit `v` of `i` =
/// variable `v`).
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct TruthTable {
    nvars: usize,
    bits: BitVec,
}

impl std::fmt::Debug for TruthTable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "TT({}v, 0x{})", self.nvars, self.bits.to_hex())
    }
}

impl TruthTable {
    /// Maximum variables for dense representation (2^20 bits = 128 KiB).
    pub const MAX_VARS: usize = 20;

    /// Constant-0 table.
    pub fn zeros(nvars: usize) -> TruthTable {
        assert!(nvars <= Self::MAX_VARS);
        TruthTable { nvars, bits: BitVec::zeros(1 << nvars) }
    }

    /// Constant-1 table.
    pub fn ones(nvars: usize) -> TruthTable {
        assert!(nvars <= Self::MAX_VARS);
        TruthTable { nvars, bits: BitVec::ones(1 << nvars) }
    }

    /// Table of the projection `f(x) = x_v` (word-parallel fill).
    pub fn var(nvars: usize, v: usize) -> TruthTable {
        assert!(v < nvars);
        let mut t = TruthTable::zeros(nvars);
        if v < 6 {
            // Within-word repetition pattern.
            const PATTERNS: [u64; 6] = [
                0xAAAA_AAAA_AAAA_AAAA,
                0xCCCC_CCCC_CCCC_CCCC,
                0xF0F0_F0F0_F0F0_F0F0,
                0xFF00_FF00_FF00_FF00,
                0xFFFF_0000_FFFF_0000,
                0xFFFF_FFFF_0000_0000,
            ];
            for w in t.bits.words_mut() {
                *w = PATTERNS[v];
            }
        } else {
            let stride = 1usize << (v - 6);
            for (i, w) in t.bits.words_mut().iter_mut().enumerate() {
                if (i / stride) % 2 == 1 {
                    *w = !0u64;
                }
            }
        }
        t.bits.mask_tail();
        t
    }

    /// Build by evaluating `f` on every assignment.
    pub fn from_fn(nvars: usize, mut f: impl FnMut(u64) -> bool) -> TruthTable {
        let mut t = TruthTable::zeros(nvars);
        for i in 0..1u64 << nvars {
            if f(i) {
                t.bits.set(i as usize, true);
            }
        }
        t
    }

    /// Build from raw bits (length must be 2^nvars).
    pub fn from_bits(nvars: usize, bits: BitVec) -> TruthTable {
        assert_eq!(bits.len(), 1 << nvars);
        TruthTable { nvars, bits }
    }

    /// Number of input variables.
    pub fn nvars(&self) -> usize {
        self.nvars
    }

    /// Access the underlying bits.
    pub fn bits(&self) -> &BitVec {
        &self.bits
    }

    /// Evaluate on one assignment.
    #[inline]
    pub fn eval(&self, assignment: u64) -> bool {
        self.bits.get(assignment as usize)
    }

    /// Set the function value on one assignment.
    #[inline]
    pub fn set_bit(&mut self, assignment: usize, v: bool) {
        self.bits.set(assignment, v);
    }

    /// The table of `f` with input variable `v` complemented:
    /// `g(x) = f(x ⊕ e_v)`. Used to absorb inverted signals into consumer
    /// LUTs when stitching netlists.
    pub fn invert_var(&self, v: usize) -> TruthTable {
        assert!(v < self.nvars);
        let mut out = TruthTable::zeros(self.nvars);
        for m in 0..1usize << self.nvars {
            if self.bits.get(m) {
                out.bits.set(m ^ (1 << v), true);
            }
        }
        out
    }

    /// Number of ON-set minterms.
    pub fn count_ones(&self) -> usize {
        self.bits.count_ones()
    }

    /// True if constant 0.
    pub fn is_zero(&self) -> bool {
        self.bits.is_zero()
    }

    /// True if constant 1.
    pub fn is_ones(&self) -> bool {
        self.bits.is_all_ones()
    }

    /// Complement.
    pub fn not(&self) -> TruthTable {
        TruthTable { nvars: self.nvars, bits: self.bits.not() }
    }

    /// Conjunction.
    pub fn and(&self, other: &TruthTable) -> TruthTable {
        assert_eq!(self.nvars, other.nvars);
        let mut b = self.bits.clone();
        b.and_assign(&other.bits);
        TruthTable { nvars: self.nvars, bits: b }
    }

    /// Disjunction.
    pub fn or(&self, other: &TruthTable) -> TruthTable {
        assert_eq!(self.nvars, other.nvars);
        let mut b = self.bits.clone();
        b.or_assign(&other.bits);
        TruthTable { nvars: self.nvars, bits: b }
    }

    /// Exclusive or.
    pub fn xor(&self, other: &TruthTable) -> TruthTable {
        assert_eq!(self.nvars, other.nvars);
        let mut b = self.bits.clone();
        b.xor_assign(&other.bits);
        TruthTable { nvars: self.nvars, bits: b }
    }

    /// Is `self ⊆ other` as ON-sets?
    pub fn implies(&self, other: &TruthTable) -> bool {
        self.bits.is_subset_of(&other.bits)
    }

    /// Does the function depend on variable `v`?
    pub fn depends_on(&self, v: usize) -> bool {
        let (c0, c1) = self.cofactors(v);
        c0 != c1
    }

    /// Positive/negative cofactors w.r.t. variable `v`, returned as tables
    /// over the same `nvars` (the cofactored variable becomes irrelevant).
    ///
    /// Word-parallel: for `v < 6` the lo/hi halves interleave within words
    /// (classic mask-and-shift with per-variable constants); for `v ≥ 6`
    /// they are whole-word strides. This is the hottest primitive of the
    /// ISOP recursion and the enumeration path — see EXPERIMENTS.md §Perf.
    pub fn cofactors(&self, v: usize) -> (TruthTable, TruthTable) {
        const MASKS: [u64; 6] = [
            0x5555_5555_5555_5555, // bit 0 clear
            0x3333_3333_3333_3333,
            0x0F0F_0F0F_0F0F_0F0F,
            0x00FF_00FF_00FF_00FF,
            0x0000_FFFF_0000_FFFF,
            0x0000_0000_FFFF_FFFF,
        ];
        let mut t0 = TruthTable::zeros(self.nvars);
        let mut t1 = TruthTable::zeros(self.nvars);
        let src = self.bits.words();
        if v < 6 {
            let m = MASKS[v];
            let sh = 1usize << v;
            let w0 = t0.bits.words_mut();
            for (d, &s) in w0.iter_mut().zip(src) {
                let lo = s & m;
                *d = lo | (lo << sh);
            }
            let w1 = t1.bits.words_mut();
            for (d, &s) in w1.iter_mut().zip(src) {
                let hi = s & !m;
                *d = hi | (hi >> sh);
            }
        } else {
            // Words alternate in runs of `stride` words: lo run, hi run.
            let stride = 1usize << (v - 6);
            let w0 = t0.bits.words_mut();
            let w1 = t1.bits.words_mut();
            let mut base = 0;
            while base < src.len() {
                for k in 0..stride.min(src.len() - base) {
                    let lo = src[base + k];
                    let hi = if base + stride + k < src.len() {
                        src[base + stride + k]
                    } else {
                        0
                    };
                    w0[base + k] = lo;
                    w0[base + stride + k] = lo;
                    w1[base + k] = hi;
                    w1[base + stride + k] = hi;
                }
                base += 2 * stride;
            }
        }
        t0.bits.mask_tail();
        t1.bits.mask_tail();
        (t0, t1)
    }

    /// Drop the top variable, keeping the `x_top = 0` half — the inverse of
    /// adding an irrelevant variable. Callers must ensure the function does
    /// not depend on the top variable (true for Shannon cofactors).
    /// Word-parallel (hot in the mux-tree synthesis fallback).
    pub fn shrink_top(&self) -> TruthTable {
        assert!(self.nvars > 0);
        let n = self.nvars - 1;
        let mut out = TruthTable::zeros(n);
        let half_bits = 1usize << n;
        if half_bits >= 64 {
            let words = half_bits / 64;
            out.bits
                .words_mut()
                .copy_from_slice(&self.bits.words()[..words]);
        } else {
            let w = self.bits.words()[0] & ((1u64 << half_bits) - 1);
            out.bits.words_mut()[0] = w;
        }
        out
    }

    /// The truth table of an SOP cover (must have the same nvars).
    pub fn from_cover(cover: &Cover) -> TruthTable {
        assert!(cover.nvars() <= Self::MAX_VARS);
        TruthTable { nvars: cover.nvars(), bits: cover.to_truth_bits() }
    }

    /// Minato–Morreale ISOP: returns a cover `C` with `on ⊆ C ⊆ on ∪ dc`,
    /// where each cube is an implicant of `on ∪ dc` and the cover is
    /// irredundant by construction. `on` and `dc` must be disjoint.
    pub fn isop(on: &TruthTable, dc: &TruthTable) -> Cover {
        assert_eq!(on.nvars, dc.nvars);
        debug_assert!(on.and(dc).is_zero(), "ON and DC must be disjoint");
        let upper = on.or(dc);
        let (cover, _tt) = isop_rec(on, &upper, on.nvars, on.nvars);
        cover
    }
}

/// Recursive ISOP on the first `k` variables; `lower`/`upper` are tables in
/// the full space that do not depend on variables ≥ k. Returns the cover and
/// its truth table (used by the caller to compute the residual lower bound).
fn isop_rec(
    lower: &TruthTable,
    upper: &TruthTable,
    k: usize,
    nvars: usize,
) -> (Cover, TruthTable) {
    debug_assert!(lower.implies(upper));
    if lower.is_zero() {
        return (Cover::empty(nvars), TruthTable::zeros(nvars));
    }
    if upper.is_ones() {
        return (Cover::universe(nvars), TruthTable::ones(nvars));
    }
    debug_assert!(k > 0, "k=0 implies constant function, handled above");
    let v = k - 1;

    let (l0, l1) = lower.cofactors(v);
    let (u0, u1) = upper.cofactors(v);

    // Minterms that can only be covered with literal x_v' / x_v.
    let l0_only = l0.and(&u1.not());
    let l1_only = l1.and(&u0.not());

    let (c0, t0) = isop_rec(&l0_only, &u0, v, nvars);
    let (c1, t1) = isop_rec(&l1_only, &u1, v, nvars);

    // Residual: minterms of lower not yet covered, must be covered without
    // the x_v literal.
    let lnew = l0.and(&t0.not()).or(&l1.and(&t1.not()));
    let udc = u0.and(&u1);
    let (cd, td) = isop_rec(&lnew, &udc, v, nvars);

    // Assemble: x'·C0 + x·C1 + Cd
    let mut cubes = Vec::with_capacity(c0.len() + c1.len() + cd.len());
    for mut c in c0.cubes {
        c.set(v, Pol::Zero);
        cubes.push(c);
    }
    for mut c in c1.cubes {
        c.set(v, Pol::One);
        cubes.push(c);
    }
    cubes.extend(cd.cubes);
    let cover = Cover::from_cubes(nvars, cubes);

    // TT of assembled cover = x'·t0 + x·t1 + td.
    let xv = TruthTable::var(nvars, v);
    let tt = xv.not().and(&t0).or(&xv.and(&t1)).or(&td);
    (cover, tt)
}

/// Convenience: exact minterm cover of a table (used by the LogicNets
/// baseline, which does *not* minimize).
pub fn minterm_cover(tt: &TruthTable) -> Cover {
    let cubes = (0..1u64 << tt.nvars())
        .filter(|&m| tt.eval(m))
        .map(|m| Cube::minterm(tt.nvars(), m))
        .collect();
    Cover::from_cubes(tt.nvars(), cubes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    fn random_tt(nvars: usize, rng: &mut Xoshiro256, density: f64) -> TruthTable {
        TruthTable::from_fn(nvars, |_| rng.bernoulli(density))
    }

    #[test]
    fn var_projection() {
        let t = TruthTable::var(3, 1);
        for i in 0..8u64 {
            assert_eq!(t.eval(i), (i >> 1) & 1 == 1);
        }
    }

    #[test]
    fn cofactors_partition() {
        let mut rng = Xoshiro256::new(1);
        let t = random_tt(5, &mut rng, 0.5);
        for v in 0..5 {
            let (c0, c1) = t.cofactors(v);
            assert!(!c0.depends_on(v));
            assert!(!c1.depends_on(v));
            // Shannon: f = x'·c0 + x·c1
            let xv = TruthTable::var(5, v);
            let recon = xv.not().and(&c0).or(&xv.and(&c1));
            assert_eq!(recon, t);
        }
    }

    #[test]
    fn depends_on_detects_support() {
        // f = x0 XOR x2 over 4 vars
        let t = TruthTable::from_fn(4, |i| ((i & 1) ^ ((i >> 2) & 1)) == 1);
        assert!(t.depends_on(0));
        assert!(!t.depends_on(1));
        assert!(t.depends_on(2));
        assert!(!t.depends_on(3));
    }

    #[test]
    fn isop_exact_when_no_dc() {
        let mut rng = Xoshiro256::new(42);
        for nvars in 0..=8 {
            for _ in 0..20 {
                let on = random_tt(nvars, &mut rng, 0.4);
                let dc = TruthTable::zeros(nvars);
                let c = TruthTable::isop(&on, &dc);
                let back = TruthTable::from_cover(&c);
                assert_eq!(back, on, "nvars={nvars}");
            }
        }
    }

    #[test]
    fn isop_respects_dc_bounds() {
        let mut rng = Xoshiro256::new(7);
        for _ in 0..50 {
            let nvars = 6;
            let on = random_tt(nvars, &mut rng, 0.3);
            let dc_raw = random_tt(nvars, &mut rng, 0.3);
            let dc = dc_raw.and(&on.not()); // disjoint
            let c = TruthTable::isop(&on, &dc);
            let back = TruthTable::from_cover(&c);
            assert!(on.implies(&back), "ON must be covered");
            assert!(back.implies(&on.or(&dc)), "must stay within ON ∪ DC");
        }
    }

    #[test]
    fn isop_xor_cube_count() {
        // ISOP of an n-var XOR needs exactly 2^(n-1) cubes (no compaction
        // possible) — a sanity anchor that the recursion doesn't blow up.
        for n in 1..=6usize {
            let on = TruthTable::from_fn(n, |i| (i.count_ones() & 1) == 1);
            let c = TruthTable::isop(&on, &TruthTable::zeros(n));
            assert_eq!(c.len(), 1 << (n - 1), "xor{n}");
        }
    }

    #[test]
    fn isop_compacts_unate_function() {
        // f = x0 + x1 + x2: ISOP should give 3 single-literal cubes, not 7
        // minterms.
        let on = TruthTable::from_fn(3, |i| i != 0);
        let c = TruthTable::isop(&on, &TruthTable::zeros(3));
        assert_eq!(c.len(), 3);
        assert_eq!(c.literal_count(), 3);
    }

    #[test]
    fn isop_constants() {
        let z = TruthTable::zeros(4);
        let o = TruthTable::ones(4);
        assert!(TruthTable::isop(&z, &z).is_empty());
        let c = TruthTable::isop(&o, &z);
        assert_eq!(c.len(), 1);
        assert!(TruthTable::from_cover(&c).is_ones());
        // Everything DC → empty cover is allowed (ON is empty).
        assert!(TruthTable::isop(&z, &o).is_empty());
    }

    #[test]
    fn minterm_cover_is_exact() {
        let mut rng = Xoshiro256::new(3);
        let t = random_tt(5, &mut rng, 0.5);
        let c = minterm_cover(&t);
        assert_eq!(c.len(), t.count_ones());
        assert_eq!(TruthTable::from_cover(&c), t);
    }

    #[test]
    fn boolean_ops() {
        let mut rng = Xoshiro256::new(9);
        let a = random_tt(6, &mut rng, 0.5);
        let b = random_tt(6, &mut rng, 0.5);
        assert_eq!(a.and(&b).not(), a.not().or(&b.not())); // De Morgan
        assert_eq!(a.xor(&b), a.and(&b.not()).or(&a.not().and(&b)));
        assert!(a.and(&b).implies(&a));
        assert!(a.implies(&a.or(&b)));
    }
}
