//! Combinational equivalence checking (CEC) via a SAT miter.
//!
//! Encodes two [`LutNetlist`]s over *shared* primary inputs into CNF
//! (Tseitin, one clause per truth-table row per LUT), XORs each output pair
//! into a difference variable, asserts that at least one difference fires,
//! and hands the formula to the in-crate CDCL solver
//! ([`crate::util::sat`]). UNSAT is a proof of equivalence over **all**
//! `2^n` input assignments — unlike `logic::verify`'s exhaustive sweep
//! (≤ 24 inputs) or its sampled mode (which can miss divergence). SAT
//! yields a concrete counterexample assignment.
//!
//! Cost scales with `2^fanin` clauses per LUT (trivial for the ≤ 6-input
//! fabric this crate maps to) and with how structurally dissimilar the two
//! netlists are; the optimizer-verification miters this module exists for
//! (pre- vs post-[`crate::logic::opt::optimize`]) share almost all their
//! structure and solve in microseconds.

use crate::logic::check::{self, CheckError};
use crate::logic::netlist::{LutNetlist, Sig};
use crate::logic::truthtable::TruthTable;
use crate::util::sat::{Lit, SatResult, Solver, Var};

/// Verdict from [`check_netlists`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CecResult {
    /// Proven equal on every input assignment.
    Equivalent,
    /// The netlists differ on `assignment` (indexed by primary input);
    /// `output` is the index of one differing output.
    Inequivalent {
        /// Witness input assignment, one bool per primary input.
        assignment: Vec<bool>,
        /// Index of a primary output on which the netlists disagree.
        output: usize,
    },
}

impl CecResult {
    /// True when proven equivalent.
    pub fn is_equivalent(&self) -> bool {
        matches!(self, CecResult::Equivalent)
    }
}

/// Prove or refute combinational equivalence of two netlists with identical
/// I/O signatures. Both netlists are structurally linted first — a malformed
/// netlist has no well-defined function to compare.
pub fn check_netlists(a: &LutNetlist, b: &LutNetlist) -> Result<CecResult, CheckError> {
    if a.num_inputs != b.num_inputs || a.outputs.len() != b.outputs.len() {
        return Err(CheckError::SignatureMismatch {
            inputs: (a.num_inputs, b.num_inputs),
            outputs: (a.outputs.len(), b.outputs.len()),
        });
    }
    check::lint_netlist(a, TruthTable::MAX_VARS)?;
    check::lint_netlist(b, TruthTable::MAX_VARS)?;

    let mut s = Solver::new();
    let inputs: Vec<Var> = (0..a.num_inputs).map(|_| s.new_var()).collect();
    // One pinned-true variable gives Const signals a literal to point at.
    let tru = s.new_var();
    s.add_clause(&[Lit::pos(tru)]);
    let va = encode_netlist(&mut s, a, &inputs, tru);
    let vb = encode_netlist(&mut s, b, &inputs, tru);

    let mut diff_vars: Vec<Var> = Vec::with_capacity(a.outputs.len());
    let mut any_diff: Vec<Lit> = Vec::with_capacity(a.outputs.len());
    for (&(sa, ia), &(sb, ib)) in a.outputs.iter().zip(&b.outputs) {
        let la = sig_lit(sa, ia, &va, &inputs, tru);
        let lb = sig_lit(sb, ib, &vb, &inputs, tru);
        let d = s.new_var();
        let dl = Lit::pos(d);
        // d ↔ la ⊕ lb
        s.add_clause(&[!dl, la, lb]);
        s.add_clause(&[!dl, !la, !lb]);
        s.add_clause(&[dl, !la, lb]);
        s.add_clause(&[dl, la, !lb]);
        diff_vars.push(d);
        any_diff.push(dl);
    }
    // A netlist pair with zero outputs is vacuously equivalent; an empty
    // OR-clause would instead claim UNSAT for the wrong reason.
    if any_diff.is_empty() {
        return Ok(CecResult::Equivalent);
    }
    s.add_clause(&any_diff);

    match s.solve() {
        SatResult::Unsat => Ok(CecResult::Equivalent),
        SatResult::Sat(model) => {
            let assignment: Vec<bool> = inputs.iter().map(|&v| model[v as usize]).collect();
            let output = diff_vars
                .iter()
                .position(|&d| model[d as usize])
                .expect("SAT model must set at least one difference variable");
            Ok(CecResult::Inequivalent { assignment, output })
        }
    }
}

/// Tseitin-encode a netlist; returns one solver variable per LUT output.
fn encode_netlist(s: &mut Solver, nl: &LutNetlist, inputs: &[Var], tru: Var) -> Vec<Var> {
    let mut lut_vars: Vec<Var> = Vec::with_capacity(nl.luts.len());
    let mut clause: Vec<Lit> = Vec::new();
    for lut in &nl.luts {
        let o = s.new_var();
        let ol = Lit::pos(o);
        let in_lits: Vec<Lit> =
            lut.inputs.iter().map(|&sig| sig_lit(sig, false, &lut_vars, inputs, tru)).collect();
        let k = in_lits.len();
        // Row m: (inputs == m) → (o == table[m]), i.e. a clause holding the
        // complement of each input's row value plus the polarized output.
        for m in 0..(1u64 << k) {
            clause.clear();
            for (i, &l) in in_lits.iter().enumerate() {
                clause.push(if (m >> i) & 1 == 1 { !l } else { l });
            }
            clause.push(if lut.table.eval(m) { ol } else { !ol });
            s.add_clause(&clause);
        }
        lut_vars.push(o);
    }
    lut_vars
}

/// Literal for a netlist signal, with an optional extra inversion (the
/// output-polarity flag).
fn sig_lit(sig: Sig, invert: bool, lut_vars: &[Var], inputs: &[Var], tru: Var) -> Lit {
    let l = match sig {
        Sig::Const(true) => Lit::pos(tru),
        Sig::Const(false) => Lit::neg(tru),
        Sig::Input(i) => Lit::pos(inputs[i as usize]),
        Sig::Lut(j) => Lit::pos(lut_vars[j as usize]),
    };
    if invert {
        !l
    } else {
        l
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::verify::exhaustive_netlists;

    fn xor_tt() -> TruthTable {
        TruthTable::from_fn(2, |m| (m.count_ones() & 1) == 1)
    }

    fn xor_chain(n: usize) -> LutNetlist {
        let mut nl = LutNetlist::new(n);
        let mut acc = Sig::Input(0);
        for i in 1..n {
            acc = nl.add_lut(vec![acc, Sig::Input(i as u32)], xor_tt());
        }
        nl.add_output(acc, false);
        nl
    }

    #[test]
    fn identical_netlists_are_equivalent() {
        let nl = xor_chain(5);
        assert_eq!(check_netlists(&nl, &nl).unwrap(), CecResult::Equivalent);
    }

    #[test]
    fn structurally_different_but_equal_functions_are_equivalent() {
        // XOR chain vs XNOR chain with inverted output.
        let a = xor_chain(4);
        let mut b = LutNetlist::new(4);
        let xnor = TruthTable::from_fn(2, |m| (m.count_ones() & 1) == 0);
        let s1 = b.add_lut(vec![Sig::Input(0), Sig::Input(1)], xor_tt());
        let s2 = b.add_lut(vec![s1, Sig::Input(2)], xor_tt());
        let s3 = b.add_lut(vec![s2, Sig::Input(3)], xnor);
        b.add_output(s3, true);
        assert_eq!(check_netlists(&a, &b).unwrap(), CecResult::Equivalent);
    }

    #[test]
    fn counterexample_is_a_real_witness() {
        let a = xor_chain(6);
        // Flip one truth-table row in a clone — inequivalent by construction
        // (the flipped LUT feeds the single output through XORs, which are
        // invertible, so the change is observable).
        let mut b = a.clone();
        let mut t = b.luts[2].table.clone();
        t.set_bit(1, !t.eval(1));
        b.luts[2].table = t;
        match check_netlists(&a, &b).unwrap() {
            CecResult::Inequivalent { assignment, output } => {
                assert_eq!(output, 0);
                let bits: u64 =
                    assignment.iter().enumerate().map(|(i, &v)| (v as u64) << i).sum();
                assert_ne!(a.eval(bits), b.eval(bits), "witness must distinguish the netlists");
            }
            CecResult::Equivalent => panic!("mutated netlist must be inequivalent"),
        }
    }

    #[test]
    fn const_and_input_outputs_are_handled() {
        let mut a = LutNetlist::new(2);
        a.add_output(Sig::Const(true), false);
        a.add_output(Sig::Input(1), true);
        // b computes the same via LUTs.
        let mut b = LutNetlist::new(2);
        let ones = b.add_lut(vec![Sig::Input(0)], TruthTable::ones(1));
        let buf = b.add_lut(vec![Sig::Input(1)], TruthTable::from_fn(1, |m| m == 1));
        b.add_output(ones, false);
        b.add_output(buf, true);
        assert_eq!(check_netlists(&a, &b).unwrap(), CecResult::Equivalent);
    }

    #[test]
    fn zero_output_netlists_are_vacuously_equivalent() {
        let a = LutNetlist::new(3);
        let b = LutNetlist::new(3);
        assert_eq!(check_netlists(&a, &b).unwrap(), CecResult::Equivalent);
    }

    #[test]
    fn signature_mismatch_is_a_typed_error() {
        let a = xor_chain(3);
        let b = xor_chain(4);
        assert!(matches!(
            check_netlists(&a, &b),
            Err(CheckError::SignatureMismatch { inputs: (3, 4), .. })
        ));
    }

    #[test]
    fn malformed_netlist_is_rejected_before_encoding() {
        let mut a = xor_chain(3);
        a.luts[0].inputs[0] = Sig::Lut(0); // self-loop
        let b = xor_chain(3);
        assert!(matches!(check_netlists(&a, &b), Err(CheckError::Cycle { .. })));
    }

    #[test]
    fn agrees_with_exhaustive_on_small_pairs() {
        let a = xor_chain(4);
        let mut b = a.clone();
        let mut t = b.luts[1].table.clone();
        t.set_bit(0, !t.eval(0));
        b.luts[1].table = t;
        let sat_says = check_netlists(&a, &b).unwrap().is_equivalent();
        let brute_says = exhaustive_netlists(&a, &b).unwrap().is_equivalent();
        assert_eq!(sat_says, brute_says);
        assert!(!sat_says);
    }

    #[test]
    fn wide_netlists_beyond_exhaustive_reach_still_prove() {
        // 40 inputs — far past the 2^24 exhaustive ceiling.
        let a = xor_chain(40);
        let b = xor_chain(40);
        assert_eq!(check_netlists(&a, &b).unwrap(), CecResult::Equivalent);
        let mut c = a.clone();
        let mut t = c.luts[20].table.clone();
        t.set_bit(2, !t.eval(2));
        c.luts[20].table = t;
        match check_netlists(&a, &c).unwrap() {
            CecResult::Inequivalent { assignment, .. } => {
                assert_eq!(assignment.len(), 40);
                let words: Vec<u64> =
                    assignment.iter().map(|&v| if v { !0u64 } else { 0 }).collect();
                assert_ne!(
                    a.simulate_words(&words)[0] & 1,
                    c.simulate_words(&words)[0] & 1,
                    "witness must distinguish the netlists"
                );
            }
            CecResult::Equivalent => panic!("mutated wide netlist must be inequivalent"),
        }
    }
}
