//! Netlist-to-native code generation — the circuit as straight-line code.
//!
//! The paper's core claim is that the circuit **is** the program: fixed-
//! function combinational logic, not an instruction stream fed to a
//! generic evaluator. The compiled simulator (`logic::sim`) is still an
//! interpreter — it walks arity runs and folds packed truth tables at run
//! time. This module removes that last layer: it lowers an optimized
//! [`CompiledNetlist`] into **branch-free straight-line Rust source**
//! (every LUT becomes a constant-folded Shannon-mux expression over `u64`
//! lane words, the levelized schedule becomes program order, scratch slots
//! become `let` bindings), drives `rustc` to build it as a `cdylib`, and
//! loads the result through dependency-free `dlopen`/`dlsym` shims.
//!
//! Why source emission + `rustc` instead of a hand-rolled JIT: the emitted
//! program is *data-independent straight-line code*, exactly what an
//! ahead-of-time optimizing compiler is best at (constant folding the
//! tables away, register-allocating the live slot window, vectorizing the
//! lane loop), and the generated `.rs` is a human-auditable artifact the
//! differential suite can pin against `LutNetlist::eval`. See
//! `rust/DESIGN.md` §Engine-API for the full ADR.
//!
//! Built libraries are cached next to the circuit bundle (or under the
//! temp dir when serving without one) keyed by **model fingerprint +
//! rustc version**: the fingerprint is baked into the `.so` as an exported
//! symbol and re-checked at every load, the rustc version lives in a
//! `.meta` sidecar; either mismatching forces a rebuild. The fallback
//! ladder when any step is unavailable (no `rustc` on the serving host,
//! non-Linux `dlopen` stub) is native → SIMD interpreter → scalar
//! interpreter — construction fails with a typed error and the caller
//! (`coordinator::router`) selects the interpreter engine.
//!
//! The `dlopen` shims follow `util::evloop`'s FFI idiom: direct
//! `extern "C"` declarations against the platform libc `std` already
//! links — no crates, no bindings generator. On non-Linux targets the
//! loader compiles to a stub whose constructor reports the platform as
//! unsupported.

use std::fmt;
use std::path::PathBuf;

use crate::logic::sim::CompiledNetlist;

/// ABI version stamped into every generated library; the loader rejects
/// anything else. Bump when the exported symbol set or layout changes.
pub const ABI_VERSION: u64 = 1;

/// Typed failure of native code generation, build, or load.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodegenError {
    /// `rustc` could not be run (not installed, not on PATH) — the caller
    /// should fall back to the interpreter.
    RustcUnavailable(String),
    /// `rustc` ran but rejected the generated source.
    Build(String),
    /// The built library could not be loaded or is missing symbols.
    Load(String),
    /// The library was generated from a different model (embedded
    /// fingerprint or netlist shape mismatch) — stale cache.
    Mismatch { expected: String, found: String },
    /// Filesystem failure around the cache.
    Io { path: String, msg: String },
}

impl fmt::Display for CodegenError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodegenError::RustcUnavailable(m) => write!(f, "rustc unavailable: {m}"),
            CodegenError::Build(m) => write!(f, "native build failed: {m}"),
            CodegenError::Load(m) => write!(f, "native library load failed: {m}"),
            CodegenError::Mismatch { expected, found } => write!(
                f,
                "native library was generated from a different model \
                 (embedded {found}, expected {expected})"
            ),
            CodegenError::Io { path, msg } => write!(f, "{path}: {msg}"),
        }
    }
}

impl std::error::Error for CodegenError {}

/// How [`load_or_build`] satisfied the request — callers surface this so
/// CI can assert that a stale `.so` was rejected and rebuilt.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CacheOutcome {
    /// The cached library matched fingerprint, rustc version, and shape.
    Cached,
    /// The library was (re)built; the reason is human-readable.
    Rebuilt(String),
}

/// `rustc -V`, trimmed — half of the cache key. Fails typed when the
/// serving host has no toolchain.
pub fn rustc_version() -> Result<String, CodegenError> {
    let out = std::process::Command::new("rustc")
        .arg("-V")
        .output()
        .map_err(|e| CodegenError::RustcUnavailable(format!("running `rustc -V`: {e}")))?;
    if !out.status.success() {
        return Err(CodegenError::RustcUnavailable(format!(
            "`rustc -V` exited with {}",
            out.status
        )));
    }
    Ok(String::from_utf8_lossy(&out.stdout).trim().to_string())
}

/// Whether a native build can work here at all (toolchain present and the
/// platform has a real `dlopen`). Tests use this to skip, not fail.
pub fn rustc_available() -> bool {
    cfg!(target_os = "linux") && rustc_version().is_ok()
}

/// Default cache location for a circuit served without a bundle file:
/// `$TMPDIR/nnt-native-<fingerprint>.so`.
pub fn default_cache_path(fingerprint: &str) -> String {
    let mut p: PathBuf = std::env::temp_dir();
    p.push(format!("nnt-native-{fingerprint}.so"));
    p.to_string_lossy().into_owned()
}

/// One selector-name table: expression string for every signal code
/// (0/1 consts, `2+i` inputs, `2+num_inputs+j` LUT bindings).
fn signal_names(num_inputs: usize, num_luts: usize) -> Vec<String> {
    let mut names = Vec::with_capacity(2 + num_inputs + num_luts);
    names.push("0u64".to_string());
    names.push("!0u64".to_string());
    for i in 0..num_inputs {
        names.push(format!("i{i}"));
    }
    for j in 0..num_luts {
        names.push(format!("t{j}"));
    }
    names
}

/// Shannon-fold a packed truth table into a branch-free expression over
/// the selector names, constant-folding as it recurses: cofactor halves
/// that agree collapse, constant cofactors reduce the mux to AND/OR/NOT.
/// The selector order matches the interpreter's `fold_block` (selector `j`
/// indexes bit `j` of the table address, so the *last* selector is the top
/// mux), which is what keeps the emitted code bit-exact by construction.
fn fold_expr(table: u64, sels: &[&str]) -> String {
    let Some((top, rest)) = sels.split_last() else {
        return if table & 1 == 1 { "!0u64".into() } else { "0u64".into() };
    };
    let half_bits = 1u32 << rest.len();
    let mask = if half_bits == 64 { !0u64 } else { (1u64 << half_bits) - 1 };
    let lo = fold_expr(table & mask, rest);
    let hi = fold_expr((table >> half_bits) & mask, rest);
    if lo == hi {
        lo // cofactors agree: the function does not depend on `top`
    } else if lo == "0u64" && hi == "!0u64" {
        (*top).to_string() // mux(s, 0, 1) = s
    } else if lo == "!0u64" && hi == "0u64" {
        format!("!{top}") // mux(s, 1, 0) = !s
    } else if lo == "0u64" {
        format!("({top} & {hi})")
    } else if hi == "0u64" {
        format!("(!{top} & {lo})")
    } else if lo == "!0u64" {
        format!("(!{top} | {hi})")
    } else if hi == "!0u64" {
        format!("({top} | {lo})")
    } else {
        format!("((!{top} & {lo}) | ({top} & {hi}))")
    }
}

/// Lower a compiled netlist into the source of a standalone `cdylib`: the
/// schedule-ordered instruction stream becomes one `let` binding per LUT,
/// each a branch-free Shannon-fold expression over 64-sample `u64` lane
/// words; the exported `nnt_eval_groups` runs it once per lane group.
pub fn emit_source(sim: &CompiledNetlist, fingerprint: &str) -> String {
    let ni = sim.num_inputs();
    let no = sim.num_outputs();
    let names = signal_names(ni, sim.num_luts());
    let mut src = String::with_capacity(4096);
    src.push_str(&format!(
        "// Generated by `nullanet codegen` — the circuit as straight-line code.\n\
         // model fingerprint: {fingerprint}. Do not edit.\n\
         #![allow(unused)]\n\n\
         const NI: usize = {ni};\n\
         const NO: usize = {no};\n\
         static FP: [u8; {fp_len}] = *b\"{fingerprint}\";\n\n\
         #[no_mangle]\n\
         pub extern \"C\" fn nnt_abi_version() -> u64 {{\n    {abi}\n}}\n\n\
         #[no_mangle]\n\
         pub extern \"C\" fn nnt_num_inputs() -> u64 {{\n    NI as u64\n}}\n\n\
         #[no_mangle]\n\
         pub extern \"C\" fn nnt_num_outputs() -> u64 {{\n    NO as u64\n}}\n\n\
         #[no_mangle]\n\
         pub extern \"C\" fn nnt_fingerprint_len() -> u64 {{\n    FP.len() as u64\n}}\n\n\
         #[no_mangle]\n\
         pub extern \"C\" fn nnt_fingerprint() -> *const u8 {{\n    FP.as_ptr()\n}}\n\n",
        fp_len = fingerprint.len(),
        abi = ABI_VERSION,
    ));
    src.push_str("#[inline(always)]\nfn eval_word(inp: &[u64; NI], out: &mut [u64; NO]) {\n");
    for i in 0..ni {
        src.push_str(&format!("    let i{i} = inp[{i}];\n"));
    }
    for (arity, table, dest, inputs) in sim.instructions() {
        let sels: Vec<&str> = inputs.iter().map(|&c| names[c as usize].as_str()).collect();
        debug_assert_eq!(sels.len(), arity as usize);
        let j = dest as usize - 2 - ni;
        src.push_str(&format!("    let t{j} = {};\n", fold_expr(table, &sels)));
    }
    for (j, &(code, inv)) in sim.output_codes().iter().enumerate() {
        let name = names[code as usize].as_str();
        if inv {
            src.push_str(&format!("    out[{j}] = !{name};\n"));
        } else {
            src.push_str(&format!("    out[{j}] = {name};\n"));
        }
    }
    src.push_str("}\n\n");
    src.push_str(
        "/// # Safety\n\
         /// `words` must point to `groups * NI` readable `u64`s (lane-group-major\n\
         /// packed batch words) and `out` to `groups * NO` writable `u64`s.\n\
         #[no_mangle]\n\
         pub unsafe extern \"C\" fn nnt_eval_groups(words: *const u64, groups: u64, out: *mut u64) {\n\
         \x20   for g in 0..groups as usize {\n\
         \x20       let inp = &*(words.add(g * NI) as *const [u64; NI]);\n\
         \x20       let o = &mut *(out.add(g * NO) as *mut [u64; NO]);\n\
         \x20       eval_word(inp, o);\n\
         \x20   }\n\
         }\n",
    );
    src
}

/// Write `source` next to `so_path` (as `<so_path>.rs`) and build it with
/// `rustc --crate-type cdylib -C opt-level=3`.
///
/// Both artifacts land crash-safely: the source goes through the store's
/// atomic write, and rustc emits to a temp path that is fsynced and
/// renamed into place only on success — a crash mid-build can never leave
/// a torn `.so` where a loadable one used to be.
pub fn build_so(source: &str, so_path: &str) -> Result<(), CodegenError> {
    if crate::util::fault::should_fail("codegen.rustc") {
        return Err(CodegenError::Build("injected fault at codegen.rustc".into()));
    }
    let src_path = format!("{so_path}.rs");
    crate::flow::store::atomic_write(&src_path, source.as_bytes()).map_err(|e| {
        CodegenError::Io { path: src_path.clone(), msg: e.to_string() }
    })?;
    let build_path = format!("{so_path}.build.{}", std::process::id());
    let out = std::process::Command::new("rustc")
        .args([
            "--edition",
            "2021",
            "--crate-type",
            "cdylib",
            "-C",
            "opt-level=3",
            "-C",
            "debuginfo=0",
            "-o",
            &build_path,
            &src_path,
        ])
        .output()
        .map_err(|e| CodegenError::RustcUnavailable(format!("running rustc: {e}")))?;
    if !out.status.success() {
        let _ = std::fs::remove_file(&build_path);
        // Char-wise cap: byte-indexed truncate could split a multi-byte
        // character in rustc's diagnostics and panic.
        let msg: String =
            String::from_utf8_lossy(&out.stderr).trim().chars().take(2000).collect();
        return Err(CodegenError::Build(msg));
    }
    crate::flow::store::promote(&build_path, so_path).map_err(|e| CodegenError::Io {
        path: so_path.to_string(),
        msg: e.to_string(),
    })?;
    Ok(())
}

#[cfg(target_os = "linux")]
mod sys {
    use std::ffi::{c_char, c_int, c_void, CStr, CString};

    const RTLD_NOW: c_int = 2;

    // Declarations against the libc `std` already links — prototypes match
    // dlopen(3), dlsym(3), dlclose(3), dlerror(3).
    extern "C" {
        fn dlopen(filename: *const c_char, flags: c_int) -> *mut c_void;
        fn dlsym(handle: *mut c_void, symbol: *const c_char) -> *mut c_void;
        fn dlclose(handle: *mut c_void) -> c_int;
        fn dlerror() -> *mut c_char;
    }

    fn last_error(context: &str) -> String {
        // SAFETY: dlerror returns null or a NUL-terminated string owned by
        // the dynamic loader; it is copied out before any further dl call.
        let msg = unsafe {
            let p = dlerror();
            if p.is_null() {
                None
            } else {
                Some(CStr::from_ptr(p).to_string_lossy().into_owned())
            }
        };
        match msg {
            Some(m) => format!("{context}: {m}"),
            None => context.to_string(),
        }
    }

    /// Owned `dlopen` handle, `dlclose`d exactly once on drop.
    pub struct Lib {
        handle: *mut c_void,
    }

    impl Lib {
        pub fn open(path: &str) -> Result<Lib, String> {
            let c = CString::new(path).map_err(|_| format!("{path}: path contains NUL"))?;
            // SAFETY: `c` is a valid NUL-terminated path. RTLD_NOW resolves
            // every relocation up front so missing symbols fail here, not
            // at call time.
            let handle = unsafe { dlopen(c.as_ptr(), RTLD_NOW) };
            if handle.is_null() {
                return Err(last_error(&format!("dlopen {path}")));
            }
            Ok(Lib { handle })
        }

        pub fn sym(&self, name: &str) -> Result<*mut c_void, String> {
            let c = CString::new(name).map_err(|_| format!("{name}: symbol contains NUL"))?;
            // SAFETY: `self.handle` came from a successful dlopen and is
            // alive for `self`'s lifetime; `c` is NUL-terminated.
            let p = unsafe { dlsym(self.handle, c.as_ptr()) };
            if p.is_null() {
                return Err(last_error(&format!("dlsym {name}")));
            }
            Ok(p)
        }
    }

    impl Drop for Lib {
        fn drop(&mut self) {
            // SAFETY: the handle came from a successful dlopen and is
            // closed exactly once, here. Function pointers resolved from it
            // are only held by `NativeLib`, which owns this `Lib` and drops
            // them together.
            unsafe { dlclose(self.handle) };
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod sys {
    use std::ffi::c_void;

    /// Stub loader: dynamic loading is wired up for Linux only; every
    /// constructor reports the platform as unsupported so the caller falls
    /// back to the interpreter engine.
    pub struct Lib {
        _private: (),
    }

    impl Lib {
        pub fn open(_path: &str) -> Result<Lib, String> {
            Err("dynamic library loading is unsupported on this platform".into())
        }

        pub fn sym(&self, _name: &str) -> Result<*mut c_void, String> {
            Err("dynamic library loading is unsupported on this platform".into())
        }
    }
}

/// A loaded native circuit library: validated ABI version and embedded
/// fingerprint, plus the resolved `nnt_eval_groups` entry point. Owns the
/// `dlopen` handle; dropping unloads the library.
pub struct NativeLib {
    _lib: sys::Lib,
    eval: unsafe extern "C" fn(*const u64, u64, *mut u64),
    num_inputs: usize,
    num_outputs: usize,
    fingerprint: String,
}

impl NativeLib {
    /// Load a built library and verify it: ABI version, embedded model
    /// fingerprint (`expected_fp`), and sane dimensions. Every failure is
    /// typed so callers can distinguish "stale cache" from "broken host".
    pub fn load(so_path: &str, expected_fp: &str) -> Result<NativeLib, CodegenError> {
        if crate::util::fault::should_fail("dlopen") {
            return Err(CodegenError::Load(format!("injected fault at dlopen ({so_path})")));
        }
        let lib = sys::Lib::open(so_path).map_err(CodegenError::Load)?;
        type GetU64 = unsafe extern "C" fn() -> u64;
        type GetPtr = unsafe extern "C" fn() -> *const u8;
        let abi = lib.sym("nnt_abi_version").map_err(CodegenError::Load)?;
        // SAFETY: the symbol was emitted by `emit_source` with exactly this
        // `extern "C" fn() -> u64` signature; transmuting the dlsym address
        // to that type is the defined way to call it.
        let abi: GetU64 = unsafe { std::mem::transmute(abi) };
        // SAFETY: calling the zero-argument C function resolved above.
        let got_abi = unsafe { abi() };
        if got_abi != ABI_VERSION {
            return Err(CodegenError::Load(format!(
                "{so_path}: ABI version {got_abi} (this build speaks {ABI_VERSION})"
            )));
        }
        let fp_len = lib.sym("nnt_fingerprint_len").map_err(CodegenError::Load)?;
        // SAFETY: symbol emitted as `extern "C" fn() -> u64` (see above).
        let fp_len: GetU64 = unsafe { std::mem::transmute(fp_len) };
        let fp_ptr = lib.sym("nnt_fingerprint").map_err(CodegenError::Load)?;
        // SAFETY: symbol emitted as `extern "C" fn() -> *const u8`.
        let fp_ptr: GetPtr = unsafe { std::mem::transmute(fp_ptr) };
        // SAFETY: `nnt_fingerprint` returns the address of a static byte
        // array inside the (still loaded) library whose length is exactly
        // `nnt_fingerprint_len()`; the bytes are copied before `lib` can
        // drop.
        let fingerprint = unsafe {
            let len = fp_len() as usize;
            let bytes = std::slice::from_raw_parts(fp_ptr(), len.min(256));
            String::from_utf8_lossy(bytes).into_owned()
        };
        if fingerprint != expected_fp {
            return Err(CodegenError::Mismatch {
                expected: expected_fp.to_string(),
                found: fingerprint,
            });
        }
        let ni = lib.sym("nnt_num_inputs").map_err(CodegenError::Load)?;
        // SAFETY: symbol emitted as `extern "C" fn() -> u64` (see above).
        let ni: GetU64 = unsafe { std::mem::transmute(ni) };
        let no = lib.sym("nnt_num_outputs").map_err(CodegenError::Load)?;
        // SAFETY: symbol emitted as `extern "C" fn() -> u64` (see above).
        let no: GetU64 = unsafe { std::mem::transmute(no) };
        let eval = lib.sym("nnt_eval_groups").map_err(CodegenError::Load)?;
        // SAFETY: symbol emitted as
        // `unsafe extern "C" fn(*const u64, u64, *mut u64)`.
        let eval: unsafe extern "C" fn(*const u64, u64, *mut u64) =
            unsafe { std::mem::transmute(eval) };
        // SAFETY: calling the zero-argument C getters resolved above.
        let (num_inputs, num_outputs) = unsafe { (ni() as usize, no() as usize) };
        Ok(NativeLib { _lib: lib, eval, num_inputs, num_outputs, fingerprint })
    }

    /// Primary inputs of the compiled-in circuit.
    pub fn num_inputs(&self) -> usize {
        self.num_inputs
    }

    /// Outputs of the compiled-in circuit.
    pub fn num_outputs(&self) -> usize {
        self.num_outputs
    }

    /// The fingerprint baked into the library at emission time.
    pub fn fingerprint(&self) -> &str {
        &self.fingerprint
    }

    /// Evaluate `groups` lane groups: `words` is lane-group-major packed
    /// input (`groups * num_inputs()` words), `out` receives group-major
    /// output words (`groups * num_outputs()`). Slice widths are checked
    /// with real assertions — the FFI boundary must never read garbage.
    pub fn eval_groups(&self, words: &[u64], groups: usize, out: &mut [u64]) {
        assert_eq!(
            words.len(),
            groups * self.num_inputs,
            "native eval: input words for {groups} groups of {} inputs",
            self.num_inputs
        );
        assert_eq!(
            out.len(),
            groups * self.num_outputs,
            "native eval: output words for {groups} groups of {} outputs",
            self.num_outputs
        );
        // SAFETY: the asserts above guarantee exactly the contract
        // `nnt_eval_groups` documents — `groups * NI` readable input words
        // and `groups * NO` writable output words — and the library stays
        // loaded for `&self`'s lifetime.
        unsafe { (self.eval)(words.as_ptr(), groups as u64, out.as_mut_ptr()) }
    }
}

/// Load the cached native library for `sim` at `so_path`, rebuilding when
/// the cache is missing, was generated from a different model (embedded
/// fingerprint mismatch), was built by a different rustc (`.meta`
/// sidecar), is shape-incompatible, or simply fails to load. Returns the
/// library plus whether the cache was hit or rebuilt (and why).
pub fn load_or_build(
    sim: &CompiledNetlist,
    fingerprint: &str,
    so_path: &str,
) -> Result<(NativeLib, CacheOutcome), CodegenError> {
    let meta_path = format!("{so_path}.meta");
    let rustc = rustc_version();
    let mut reason = String::new();
    if std::path::Path::new(so_path).exists() {
        let meta = std::fs::read_to_string(&meta_path).unwrap_or_default();
        let stale_rustc = match &rustc {
            Ok(v) => !meta.trim().is_empty() && meta.trim() != v,
            Err(_) => false, // can't rebuild anyway; trust the cache
        };
        if stale_rustc {
            reason = format!(
                "cached library was built by `{}`, current is `{}`",
                meta.trim(),
                rustc.as_ref().unwrap_or(&String::new())
            );
        } else {
            match NativeLib::load(so_path, fingerprint) {
                Ok(lib)
                    if lib.num_inputs() == sim.num_inputs()
                        && lib.num_outputs() == sim.num_outputs() =>
                {
                    return Ok((lib, CacheOutcome::Cached));
                }
                Ok(lib) => {
                    reason = format!(
                        "cached library has shape {}x{}, circuit is {}x{}",
                        lib.num_inputs(),
                        lib.num_outputs(),
                        sim.num_inputs(),
                        sim.num_outputs()
                    );
                }
                Err(e) => reason = e.to_string(),
            }
        }
    } else {
        reason = format!("no cached library at {so_path}");
    }
    let rustc = rustc?;
    build_so(&emit_source(sim, fingerprint), so_path)?;
    let lib = NativeLib::load(so_path, fingerprint)?;
    // Best-effort sidecar: losing it only costs a spurious rebuild later.
    let _ = crate::flow::store::atomic_write(&meta_path, rustc.as_bytes());
    Ok((lib, CacheOutcome::Rebuilt(reason)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::netlist::{LutNetlist, Sig};
    use crate::logic::truthtable::TruthTable;
    use crate::util::bitvec::PackedBatch;
    use crate::util::prng::Xoshiro256;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn tmp_so(tag: &str) -> String {
        static N: AtomicUsize = AtomicUsize::new(0);
        let n = N.fetch_add(1, Ordering::Relaxed);
        let mut p = std::env::temp_dir();
        p.push(format!("nnt-codegen-test-{}-{tag}-{n}.so", std::process::id()));
        p.to_string_lossy().into_owned()
    }

    fn random_netlist(seed: u64, num_inputs: usize, num_luts: usize) -> LutNetlist {
        let mut rng = Xoshiro256::new(seed);
        let mut nl = LutNetlist::new(num_inputs);
        for j in 0..num_luts {
            let max_sig = num_inputs + j;
            let k = 1 + rng.below(5.min(max_sig as u64)) as usize;
            let mut inputs = Vec::with_capacity(k);
            for _ in 0..k {
                let pick = rng.below(max_sig as u64) as usize;
                inputs.push(if pick < num_inputs {
                    Sig::Input(pick as u32)
                } else {
                    Sig::Lut((pick - num_inputs) as u32)
                });
            }
            let tt = TruthTable::from_fn(k, |_| rng.bernoulli(0.5));
            nl.add_lut(inputs, tt);
        }
        for j in num_luts.saturating_sub(3)..num_luts {
            nl.add_output(Sig::Lut(j as u32), rng.bernoulli(0.5));
        }
        nl.add_output(Sig::Const(true), false);
        nl.add_output(Sig::Input(0), true);
        nl
    }

    #[test]
    fn fold_expr_constant_folds() {
        // mux(s, 0, 1) = s; mux(s, 1, 0) = !s; independent cofactors drop.
        assert_eq!(fold_expr(0b10, &["i0"]), "i0");
        assert_eq!(fold_expr(0b01, &["i0"]), "!i0");
        assert_eq!(fold_expr(0b11, &["i0"]), "!0u64");
        assert_eq!(fold_expr(0b00, &["i0"]), "0u64");
        // AND: only minterm 3 set over (i0, i1).
        assert_eq!(fold_expr(0b1000, &["i0", "i1"]), "(i1 & i0)");
        // table independent of the second selector
        assert_eq!(fold_expr(0b1010, &["i0", "i1"]), "i0");
    }

    #[test]
    fn emitted_source_is_straight_line() {
        let nl = random_netlist(7, 6, 14);
        let sim = CompiledNetlist::compile(&nl);
        let src = emit_source(&sim, "00000000deadbeef");
        // Branch-free body: no `if`, `match`, or `while` in eval_word.
        let body = src.split("fn eval_word").nth(1).unwrap();
        let body = body.split("fn nnt_eval_groups").next().unwrap();
        for kw in ["if ", "match ", "while ", "loop "] {
            assert!(!body.contains(kw), "eval_word must be straight-line, found {kw:?}");
        }
        // One binding per compiled LUT, one store per output.
        assert_eq!(body.matches("    let t").count(), sim.num_luts());
        assert_eq!(body.matches("    out[").count(), sim.num_outputs());
        assert!(src.contains("nnt_eval_groups"));
        assert!(src.contains("*b\"00000000deadbeef\""));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // spawns rustc and dlopens — not a Miri workload
    fn built_library_matches_reference_eval() {
        if !rustc_available() {
            eprintln!("skipping: rustc or dlopen unavailable on this host");
            return;
        }
        let nl = random_netlist(42, 7, 20);
        let sim = CompiledNetlist::compile(&nl);
        let so = tmp_so("diff");
        let (lib, outcome) = load_or_build(&sim, "cafebabe00000001", &so).unwrap();
        assert!(matches!(outcome, CacheOutcome::Rebuilt(_)));
        let mut rng = Xoshiro256::new(9);
        let samples: Vec<u64> = (0..300).map(|_| rng.next_u64() & 0x7F).collect();
        let mut packed = PackedBatch::with_capacity(7, samples.len());
        for &bits in &samples {
            packed.push_sample_word(bits);
        }
        let groups = packed.num_groups();
        let no = sim.num_outputs();
        let mut out = vec![0u64; groups * no];
        lib.eval_groups(packed.words(), groups, &mut out);
        for (s, &bits) in samples.iter().enumerate() {
            let want = nl.eval(bits);
            for (j, &w) in want.iter().enumerate() {
                let got = (out[(s >> 6) * no + j] >> (s & 63)) & 1 == 1;
                assert_eq!(got, w, "sample={s} output={j}");
            }
        }
        // Second load is a cache hit; a wrong fingerprint is a typed reject.
        let (_lib2, outcome2) = load_or_build(&sim, "cafebabe00000001", &so).unwrap();
        assert_eq!(outcome2, CacheOutcome::Cached);
        match NativeLib::load(&so, "0000000000000000") {
            Err(CodegenError::Mismatch { expected, found }) => {
                assert_eq!(expected, "0000000000000000");
                assert_eq!(found, "cafebabe00000001");
            }
            other => panic!("expected fingerprint mismatch, got {other:?}"),
        }
        let _ = std::fs::remove_file(&so);
        let _ = std::fs::remove_file(format!("{so}.rs"));
        let _ = std::fs::remove_file(format!("{so}.meta"));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // spawns rustc and dlopens — not a Miri workload
    fn stale_cache_is_rejected_and_rebuilt() {
        if !rustc_available() {
            eprintln!("skipping: rustc or dlopen unavailable on this host");
            return;
        }
        // Build a library for netlist A, then ask for netlist B at the same
        // cache path: the embedded fingerprint must force a rebuild.
        let a = CompiledNetlist::compile(&random_netlist(1, 6, 12));
        let b = CompiledNetlist::compile(&random_netlist(2, 6, 12));
        let so = tmp_so("stale");
        let (_, first) = load_or_build(&a, "aaaaaaaaaaaaaaaa", &so).unwrap();
        assert!(matches!(first, CacheOutcome::Rebuilt(_)));
        let (lib, second) = load_or_build(&b, "bbbbbbbbbbbbbbbb", &so).unwrap();
        match second {
            CacheOutcome::Rebuilt(reason) => {
                assert!(reason.contains("different model"), "reason: {reason}")
            }
            CacheOutcome::Cached => panic!("stale cache must not be served"),
        }
        assert_eq!(lib.fingerprint(), "bbbbbbbbbbbbbbbb");
        let _ = std::fs::remove_file(&so);
        let _ = std::fs::remove_file(format!("{so}.rs"));
        let _ = std::fs::remove_file(format!("{so}.meta"));
    }
}
