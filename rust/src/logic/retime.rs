//! Min-period retiming of pipelined LUT circuits.
//!
//! The paper's logic-minimization module performs retiming (via Vivado) to
//! raise fmax: pipeline registers move across LUT boundaries so the worst
//! combinational depth between any two register stages is minimized, without
//! changing latency (stage count) or function. For a layered feed-forward
//! circuit with unit LUT delay this is solvable exactly: binary-search the
//! target depth `d`, checking feasibility with an ASAP packing (each LUT
//! takes the earliest stage where its fanins' depths allow ≤ d); among
//! feasible assignments an ALAP variant is also computed and the one with
//! fewer flip-flops wins.

use crate::logic::netlist::{PipelinedCircuit, Sig};

/// Result summary of a retiming run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetimeStats {
    pub depth_before: u32,
    pub depth_after: u32,
    pub ffs_before: usize,
    pub ffs_after: usize,
}

/// Retime `circuit` to the minimum achievable stage depth at the same
/// latency. Returns the improved circuit and statistics.
pub fn retime_min_period(circuit: &PipelinedCircuit) -> (PipelinedCircuit, RetimeStats) {
    let before = circuit.stats();
    let s = circuit.num_stages;
    let n = circuit.netlist.luts.len();
    if n == 0 {
        return (
            circuit.clone(),
            RetimeStats {
                depth_before: before.max_stage_depth,
                depth_after: before.max_stage_depth,
                ffs_before: before.ffs,
                ffs_after: before.ffs,
            },
        );
    }

    // Binary search the smallest feasible depth.
    let mut lo = 1u32;
    let mut hi = before.max_stage_depth.max(1);
    let mut best = None;
    while lo <= hi {
        let mid = (lo + hi) / 2;
        if let Some(stages) = asap_stages(circuit, mid) {
            best = Some((mid, stages));
            if mid == 1 {
                break;
            }
            hi = mid - 1;
        } else {
            lo = mid + 1;
        }
    }
    let (d, asap) = best.expect("original depth is always feasible");

    // ALAP at the same depth; choose the assignment with fewer FFs.
    let candidates: Vec<Vec<u32>> = match alap_stages(circuit, d) {
        Some(alap) => vec![asap.clone(), alap],
        None => vec![asap.clone()],
    };
    let mut best_circuit: Option<PipelinedCircuit> = None;
    let mut best_ffs = usize::MAX;
    for st in candidates {
        let c = PipelinedCircuit {
            netlist: circuit.netlist.clone(),
            stage_of_lut: st,
            num_stages: s,
        };
        debug_assert!(c.check_stages().is_ok());
        let ffs = c.count_ffs();
        if ffs < best_ffs {
            best_ffs = ffs;
            best_circuit = Some(c);
        }
    }
    let mut out = best_circuit.unwrap();
    reduce_ffs(&mut out, d);
    let after = out.stats();
    (
        out,
        RetimeStats {
            depth_before: before.max_stage_depth,
            depth_after: after.max_stage_depth,
            ffs_before: before.ffs,
            ffs_after: after.ffs,
        },
    )
}

/// Register-minimization phase (the second Leiserson–Saxe objective): at the
/// fixed period `d`, greedily move individual LUTs between stages whenever
/// that reduces the number of boundary crossings, until a fixed point.
/// Legality (edge monotonicity + intra-stage depth ≤ d) is re-checked for
/// every candidate move.
fn reduce_ffs(c: &mut PipelinedCircuit, d: u32) {
    let n = c.netlist.luts.len();
    // The greedy pass re-evaluates global cost per candidate move (O(n) per
    // probe); past ~4k LUTs that becomes the flow's bottleneck for a
    // second-order metric, so large circuits keep the ASAP/ALAP choice.
    if n == 0 || n > 4_000 {
        return;
    }
    // fanout lists
    let mut fanouts: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, lut) in c.netlist.luts.iter().enumerate() {
        for s in &lut.inputs {
            if let Sig::Lut(j) = s {
                fanouts[*j as usize].push(i);
            }
        }
    }
    let mut best_ffs = c.count_ffs();
    for _round in 0..8 {
        let mut improved = false;
        for i in 0..n {
            let cur = c.stage_of_lut[i];
            for cand in [cur.wrapping_sub(1), cur + 1] {
                if cand >= c.num_stages || (cand == u32::MAX) {
                    continue;
                }
                // Edge legality.
                let lut = &c.netlist.luts[i];
                let fanin_ok = lut.inputs.iter().all(|s| match s {
                    Sig::Lut(j) => c.stage_of_lut[*j as usize] <= cand,
                    _ => true,
                });
                let fanout_ok = fanouts[i]
                    .iter()
                    .all(|&w| c.stage_of_lut[w] >= cand);
                if !fanin_ok || !fanout_ok {
                    continue;
                }
                let old = c.stage_of_lut[i];
                c.stage_of_lut[i] = cand;
                // Depth legality (cheap full recompute: stage_depths is
                // O(n); rounds are few).
                let depth_ok = c.stage_depths().iter().all(|&x| x <= d);
                if depth_ok {
                    let ffs = c.count_ffs();
                    if ffs < best_ffs {
                        best_ffs = ffs;
                        improved = true;
                        continue;
                    }
                }
                c.stage_of_lut[i] = old;
            }
        }
        if !improved {
            break;
        }
    }
}

/// ASAP packing: earliest stage per LUT such that intra-stage depth ≤ d.
/// Returns `None` if more than `num_stages` stages would be needed.
fn asap_stages(circuit: &PipelinedCircuit, d: u32) -> Option<Vec<u32>> {
    let nl = &circuit.netlist;
    let s_max = circuit.num_stages;
    let mut stage = vec![0u32; nl.luts.len()];
    let mut depth = vec![0u32; nl.luts.len()];
    for (i, lut) in nl.luts.iter().enumerate() {
        let mut st = 0u32;
        for sig in &lut.inputs {
            if let Sig::Lut(j) = sig {
                st = st.max(stage[*j as usize]);
            }
        }
        // Depth if placed at `st`.
        let mut dep = 1u32;
        for sig in &lut.inputs {
            if let Sig::Lut(j) = sig {
                let j = *j as usize;
                if stage[j] == st {
                    dep = dep.max(depth[j] + 1);
                }
            }
        }
        if dep > d {
            st += 1;
            dep = 1;
        }
        if st >= s_max {
            return None;
        }
        stage[i] = st;
        depth[i] = dep;
    }
    Some(stage)
}

/// ALAP packing: latest stage per LUT (reverse pass), same feasibility rule.
fn alap_stages(circuit: &PipelinedCircuit, d: u32) -> Option<Vec<u32>> {
    let nl = &circuit.netlist;
    let s_max = circuit.num_stages;
    let n = nl.luts.len();
    // fanouts
    let mut fanouts: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, lut) in nl.luts.iter().enumerate() {
        for sig in &lut.inputs {
            if let Sig::Lut(j) = sig {
                fanouts[*j as usize].push(i);
            }
        }
    }
    let is_output: Vec<bool> = {
        let mut v = vec![false; n];
        for (sig, _) in &nl.outputs {
            if let Sig::Lut(j) = sig {
                v[*j as usize] = true;
            }
        }
        v
    };
    let mut stage = vec![0i64; n];
    let mut codep = vec![0u32; n]; // depth measured from the consumer side
    for i in (0..n).rev() {
        let mut st = (s_max - 1) as i64;
        for &w in &fanouts[i] {
            st = st.min(stage[w]);
        }
        if is_output[i] {
            st = st.min((s_max - 1) as i64);
        }
        let mut dep = 1u32;
        for &w in &fanouts[i] {
            if stage[w] == st {
                dep = dep.max(codep[w] + 1);
            }
        }
        if dep > d {
            st -= 1;
            dep = 1;
        }
        if st < 0 {
            return None;
        }
        stage[i] = st;
        codep[i] = dep;
    }
    Some(stage.into_iter().map(|s| s as u32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::netlist::LutNetlist;
    use crate::logic::truthtable::TruthTable;

    fn inv() -> TruthTable {
        TruthTable::from_fn(1, |m| m == 0)
    }

    /// Chain of `n` inverters over `stages` stages, all initially in stage 0
    /// except forced legality.
    fn chain(n: usize, stages: u32, initial: impl Fn(usize) -> u32) -> PipelinedCircuit {
        let mut nl = LutNetlist::new(1);
        let mut prev = Sig::Input(0);
        for _ in 0..n {
            prev = nl.add_lut(vec![prev], inv());
        }
        nl.add_output(prev, false);
        PipelinedCircuit {
            netlist: nl,
            stage_of_lut: (0..n).map(initial).collect(),
            num_stages: stages,
        }
    }

    #[test]
    fn balances_unbalanced_chain() {
        // 8 inverters, 2 stages, all in stage 0 → depth 8. Retiming must
        // reach depth 4.
        let c = chain(8, 2, |_| 0);
        assert_eq!(c.stats().max_stage_depth, 8);
        let (r, st) = retime_min_period(&c);
        r.check_stages().unwrap();
        assert_eq!(st.depth_after, 4);
        assert_eq!(r.stats().max_stage_depth, 4);
        // Function unchanged.
        for m in 0..2u64 {
            assert_eq!(r.eval(m), c.eval(m));
        }
    }

    #[test]
    fn perfect_split_across_many_stages() {
        let c = chain(12, 4, |_| 0);
        let (r, st) = retime_min_period(&c);
        assert_eq!(st.depth_after, 3);
        r.check_stages().unwrap();
    }

    #[test]
    fn already_balanced_unchanged_depth() {
        let c = chain(4, 2, |i| if i < 2 { 0 } else { 1 });
        assert_eq!(c.stats().max_stage_depth, 2);
        let (_, st) = retime_min_period(&c);
        assert_eq!(st.depth_after, 2);
    }

    #[test]
    fn single_stage_is_noop() {
        let c = chain(5, 1, |_| 0);
        let (r, st) = retime_min_period(&c);
        assert_eq!(st.depth_after, 5);
        assert_eq!(r.num_stages, 1);
    }

    #[test]
    fn diamond_structure() {
        // in → a; a feeds b and c (parallel chains of different length);
        // d = xor(b, c). 2 stages.
        let xor2 = TruthTable::from_fn(2, |m| (m.count_ones() & 1) == 1);
        let mut nl = LutNetlist::new(1);
        let a = nl.add_lut(vec![Sig::Input(0)], inv());
        let b1 = nl.add_lut(vec![a], inv());
        let b2 = nl.add_lut(vec![b1], inv());
        let b3 = nl.add_lut(vec![b2], inv());
        let c1 = nl.add_lut(vec![a], inv());
        let d = nl.add_lut(vec![b3, c1], xor2);
        nl.add_output(d, false);
        let c = PipelinedCircuit {
            netlist: nl,
            stage_of_lut: vec![0; 6],
            num_stages: 2,
        };
        assert_eq!(c.stats().max_stage_depth, 5);
        let (r, st) = retime_min_period(&c);
        r.check_stages().unwrap();
        assert!(st.depth_after <= 3, "got {}", st.depth_after);
        for m in 0..2u64 {
            assert_eq!(r.eval(m), c.eval(m));
        }
    }

    #[test]
    fn ff_count_does_not_explode() {
        let c = chain(8, 4, |_| 0);
        let (r, st) = retime_min_period(&c);
        assert_eq!(st.depth_after, 2);
        // FFs: input reg + 3 crossings + output reg = manageable; the exact
        // value depends on ASAP/ALAP choice but must stay ≤ chain length + 2.
        assert!(r.count_ffs() <= 10, "ffs={}", r.count_ffs());
    }
}
