//! LogicNets baseline flow (Umuroglu et al. [34]).
//!
//! LogicNets also converts fanin-constrained quantized neurons into LUTs,
//! but *without* two-level minimization, don't-care exploitation, or
//! cross-neuron logic sharing: every neuron output bit is realized directly
//! as one (γ·β)-input truth table, decomposed into the fabric's 6-LUTs by a
//! Shannon mux tree (the "LUT cost" model of their paper, eq. 1:
//! `O(2^(γ·β-4))` per bit). This module reimplements that construction so
//! Table I's comparison factors are measured, not transcribed.

use crate::logic::netlist::{LutNetlist, PipelinedCircuit, Sig};
use crate::logic::truthtable::TruthTable;
use crate::nn::enumerate::enumerate_neuron;
use crate::nn::model::Model;

/// Result of the baseline construction.
pub struct LogicNetsResult {
    pub circuit: PipelinedCircuit,
}

/// Build the LogicNets-style circuit for a model: direct per-bit truth-table
/// decomposition, one pipeline stage per layer (their architecture registers
/// every layer).
pub fn build_logicnets(model: &Model, lut_k: usize) -> Result<LogicNetsResult, String> {
    model.validate()?;
    let mut flat = LutNetlist::new(model.input_bits());
    let mut stages: Vec<u32> = Vec::new();
    // wires feeding the current layer (no inversions here: decomposition
    // emits plain tables)
    let mut wires: Vec<Sig> = (0..model.input_bits())
        .map(|i| Sig::Input(i as u32))
        .collect();

    for (l, layer) in model.layers.iter().enumerate() {
        let in_bits_per = model.in_quant_of_layer(l).bits;
        let out_bits_per = layer.act.bits;
        let mut next_wires = Vec::with_capacity(layer.out_width * out_bits_per);
        for neuron in 0..layer.out_width {
            let f = enumerate_neuron(model, l, neuron, None);
            // input signals of this neuron, LSB-first per masked input
            let sigs: Vec<Sig> = layer.mask[neuron]
                .iter()
                .flat_map(|&src| (0..in_bits_per).map(move |b| src * in_bits_per + b))
                .map(|w| wires[w])
                .collect();
            for table in &f.on {
                let out = decompose(&mut flat, &mut stages, l as u32, table, &sigs, lut_k);
                next_wires.push(out);
            }
        }
        wires = next_wires;
    }
    for s in wires {
        flat.add_output(s, false);
    }
    let circuit = PipelinedCircuit {
        netlist: flat,
        stage_of_lut: stages,
        num_stages: model.layers.len() as u32,
    };
    circuit.check_stages().map_err(|e| format!("logicnets: {e}"))?;
    Ok(LogicNetsResult { circuit })
}

/// Shannon mux-tree decomposition of an L-input table into k-LUTs:
/// `L ≤ k` → one LUT; otherwise split on the top variable and combine the
/// two cofactor networks with a 3-input mux LUT.
fn decompose(
    nl: &mut LutNetlist,
    stages: &mut Vec<u32>,
    stage: u32,
    table: &TruthTable,
    sigs: &[Sig],
    k: usize,
) -> Sig {
    assert_eq!(table.nvars(), sigs.len());
    if table.nvars() <= k {
        let s = nl.add_lut(sigs.to_vec(), table.clone());
        stages.push(stage);
        return s;
    }
    let top = table.nvars() - 1;
    let (c0, c1) = table.cofactors(top);
    // Cofactors as tables over the remaining vars (word-level shrink).
    let c0r = c0.shrink_top();
    let c1r = c1.shrink_top();
    let lo = decompose(nl, stages, stage, &c0r, &sigs[..top], k);
    let hi = decompose(nl, stages, stage, &c1r, &sigs[..top], k);
    // mux(sel, hi, lo): vars (lo, hi, sel) LSB-first
    let mux = TruthTable::from_fn(3, |m| {
        let (lo_v, hi_v, sel) = (m & 1 == 1, (m >> 1) & 1 == 1, (m >> 2) & 1 == 1);
        if sel {
            hi_v
        } else {
            lo_v
        }
    });
    let s = nl.add_lut(vec![lo, hi, sigs[top]], mux);
    stages.push(stage);
    s
}

/// Closed-form LogicNets LUT cost per neuron output bit (their eq. 1 shape):
/// number of k-LUTs the mux decomposition of an L-input function uses.
pub fn lut_cost_per_bit(input_bits: usize, k: usize) -> usize {
    if input_bits <= k {
        1
    } else {
        2 * lut_cost_per_bit(input_bits - 1, k) + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::eval::{bits_to_codes, codes_to_bits, forward_codes};
    use crate::nn::model::random_model;

    #[test]
    fn baseline_is_functionally_exact() {
        let m = random_model("b", 5, &[4, 3], 2, 1, 31);
        let r = build_logicnets(&m, 6).unwrap();
        let sim = crate::logic::sim::CompiledNetlist::compile(&r.circuit.netlist);
        for bits in 0..1u64 << 5 {
            let in_codes: Vec<usize> = (0..5).map(|i| ((bits >> i) & 1) as usize).collect();
            let want = forward_codes(&m, &in_codes).codes.last().unwrap().clone();
            let in_bools = codes_to_bits(&in_codes, 1);
            let got_bits = sim.run_batch(&[in_bools]).pop().unwrap();
            assert_eq!(bits_to_codes(&got_bits, m.layers[1].act.bits), want);
        }
    }

    #[test]
    fn baseline_matches_nullanet_flow_function() {
        use crate::flow::{run_flow, FlowConfig};
        let m = random_model("cmp", 6, &[4, 3], 3, 2, 5);
        let ours = run_flow(&m, &FlowConfig { jobs: 1, ..Default::default() }, None).unwrap();
        let theirs = build_logicnets(&m, 6).unwrap();
        // Same model ⇒ identical I/O behaviour.
        let sa = crate::logic::sim::CompiledNetlist::compile(&ours.circuit.netlist);
        let sb = crate::logic::sim::CompiledNetlist::compile(&theirs.circuit.netlist);
        use crate::util::prng::Xoshiro256;
        let mut rng = Xoshiro256::new(9);
        let samples: Vec<Vec<bool>> = (0..200)
            .map(|_| (0..12).map(|_| rng.bernoulli(0.5)).collect())
            .collect();
        assert_eq!(sa.run_batch(&samples), sb.run_batch(&samples));
    }

    #[test]
    fn nullanet_flow_uses_fewer_luts() {
        use crate::flow::{run_flow, FlowConfig};
        // γ·β = 8 > 6 forces the baseline into mux decomposition — the
        // regime the paper's Table I compares.
        let m = random_model("sz", 10, &[8, 5], 4, 2, 17);
        let ours = run_flow(&m, &FlowConfig { jobs: 2, ..Default::default() }, None).unwrap();
        let theirs = build_logicnets(&m, 6).unwrap();
        let a = ours.circuit.netlist.num_luts();
        let b = theirs.circuit.netlist.num_luts();
        assert!(a < b, "nullanet {a} LUTs vs logicnets {b}");
    }

    #[test]
    fn lut_cost_formula() {
        assert_eq!(lut_cost_per_bit(6, 6), 1);
        assert_eq!(lut_cost_per_bit(7, 6), 3);
        assert_eq!(lut_cost_per_bit(8, 6), 7);
        assert_eq!(lut_cost_per_bit(12, 6), 127);
    }

    #[test]
    fn decomposition_cost_matches_formula() {
        // A 8-input parity (worst case) must use exactly lut_cost(8) LUTs.
        let mut nl = LutNetlist::new(8);
        let mut stages = Vec::new();
        let t = TruthTable::from_fn(8, |m| (m.count_ones() & 1) == 1);
        let sigs: Vec<Sig> = (0..8).map(Sig::Input).collect();
        let out = decompose(&mut nl, &mut stages, 0, &t, &sigs, 6);
        nl.add_output(out, false);
        assert_eq!(nl.num_luts(), lut_cost_per_bit(8, 6));
        for m in (0..256u64).step_by(3) {
            assert_eq!(nl.eval(m)[0], (m.count_ones() & 1) == 1);
        }
    }
}
