//! Baseline systems the paper compares against: LogicNets [34] (rebuilt
//! from first principles) and the Google AQP design [38] (analytical cost
//! model; see DESIGN.md §4 for the substitution rationale).

pub mod aqp;
pub mod logicnets;

pub use aqp::AqpModel;
pub use logicnets::{build_logicnets, LogicNetsResult};
