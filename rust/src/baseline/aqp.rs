//! Cost model of Google's automatic heterogeneous-quantization design
//! (Coelho et al. [38], "AQP" — QKeras + hls4ml on the same JSC task).
//!
//! The paper's second headline claims 9.25× lower latency than this design.
//! [38] implements the JSC MLP as a *conventional arithmetic datapath*
//! (multipliers/adder trees in LUTs+DSPs, II=1, ~200 MHz class clocks on the
//! same VU9P-generation fabric); its reported best-latency configuration
//! finishes in ~10–15 clock cycles at 5 ns each (≈ 60–75 ns total). We model
//! that datapath analytically — cycles = per-layer (mult + log₂-adder-tree +
//! activation) pipeline — with the clock fixed to the published 200 MHz.
//! This is a documented *model*, not a reimplementation of hls4ml (DESIGN.md
//! §4); only the latency ratio's shape is consumed by the H2 bench.

use crate::nn::model::Model;

/// Parameters of the arithmetic-datapath model.
#[derive(Clone, Copy, Debug)]
pub struct AqpModel {
    /// Clock of the HLS design (MHz); [38] reports ≈200 MHz on VU9P-class.
    pub clock_mhz: f64,
    /// Pipeline cycles per layer for multiply + quantized activation.
    pub mult_act_cycles: u32,
    /// Adder-tree levels retired per pipeline cycle (DSP cascades chain two
    /// additions per cycle in the hls4ml designs).
    pub adder_levels_per_cycle: u32,
}

impl Default for AqpModel {
    fn default() -> Self {
        AqpModel { clock_mhz: 200.0, mult_act_cycles: 1, adder_levels_per_cycle: 2 }
    }
}

impl AqpModel {
    /// Total pipeline cycles for a model (dense layers: full fan-in).
    pub fn cycles(&self, model: &Model) -> u32 {
        model
            .layers
            .iter()
            .map(|l| {
                let fanin = l.in_width.max(2) as f64;
                let adder_levels = fanin.log2().ceil() as u32;
                self.mult_act_cycles
                    + adder_levels.div_ceil(self.adder_levels_per_cycle)
            })
            .sum()
    }

    /// End-to-end latency (ns).
    pub fn latency_ns(&self, model: &Model) -> f64 {
        self.cycles(model) as f64 * 1e3 / self.clock_mhz
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::random_model;

    #[test]
    fn latency_scales_with_depth_and_width() {
        let shallow = random_model("s", 16, &[32, 5], 4, 2, 1);
        let deep = random_model("d", 16, &[64, 64, 64, 5], 4, 2, 1);
        let m = AqpModel::default();
        assert!(m.latency_ns(&deep) > m.latency_ns(&shallow));
    }

    #[test]
    fn jsc_m_lands_in_published_band() {
        // [38]'s best designs: ~60–75 ns on the JSC task. Our JSC-M-shaped
        // model should land in that band.
        let m = random_model("jsc-m", 16, &[64, 32, 32, 5], 4, 2, 1);
        let lat = AqpModel::default().latency_ns(&m);
        assert!((40.0..110.0).contains(&lat), "AQP latency {lat} ns");
    }

    #[test]
    fn cycles_formula() {
        let m = random_model("x", 16, &[8, 4], 2, 1, 1);
        // layer0: fanin 16 → ⌈4/2⌉+1 = 3; layer1: fanin 8 → ⌈3/2⌉+1 = 3
        assert_eq!(AqpModel::default().cycles(&m), 6);
    }
}
