//! Seeded property/fuzz suite for the binary frame parser (ISSUE 8).
//!
//! The frame layer's contract is that [`frame::decode`] is a pure function
//! over an accumulation buffer: any split of the byte stream across reads
//! parses identically, every strict prefix of a valid frame is "incomplete"
//! (never an error), hostile length prefixes are rejected from the header
//! alone, and no input — corrupted or pure byte soup — can panic the parser
//! or make it consume past the buffer. Each property is seeded and
//! replayable (`NNT_PROPTEST_SEED`), with shrinking where the case shape
//! allows it.

use nullanet_tiny::coordinator::frame::{
    self, Frame, FrameError, HEADER_LEN, MAX_FRAME_PAYLOAD,
};
use nullanet_tiny::util::bitvec::PackedBatch;
use nullanet_tiny::util::proptest::{check, check_simple, Config, Gen};

/// A random well-formed classify request (tail bits masked per the wire
/// invariant). Half the cases carry a deadline budget and encode as the
/// `TYPE_CLASSIFY_REQ_DL` variant.
#[derive(Clone, Debug)]
struct ReqCase {
    model: Option<String>,
    bits: u16,
    words: Vec<u64>,
    deadline_ms: Option<u32>,
}

fn gen_req(g: &mut Gen) -> ReqCase {
    let bits = g.sized_range(1, 150) as u16; // crosses the 64/128 word edges
    let samples = g.sized_range(1, 24);
    let wps = frame::words_per_sample(bits);
    let tail = bits as usize & 63;
    let mut words = Vec::with_capacity(samples * wps);
    for _ in 0..samples {
        for w in 0..wps {
            let mut v = g.rng.next_u64();
            if w == wps - 1 && tail != 0 {
                v &= (1u64 << tail) - 1;
            }
            words.push(v);
        }
    }
    let model = match g.rng.below(3) {
        0 => None,
        1 => Some("m".to_string()),
        _ => Some(format!("model-{}", g.rng.below(100))),
    };
    let deadline_ms = match g.rng.below(4) {
        0 => Some(0),
        1 => Some(g.rng.next_u32()),
        _ => None, // plain TYPE_CLASSIFY_REQ
    };
    ReqCase { model, bits, words, deadline_ms }
}

fn encode(c: &ReqCase) -> Vec<u8> {
    let model = c.model.as_deref();
    match c.deadline_ms {
        Some(ms) => frame::encode_classify_req_deadline(model, c.bits, &c.words, ms),
        None => frame::encode_classify_req(model, c.bits, &c.words),
    }
}

#[test]
fn classify_req_round_trips_bit_exactly() {
    check_simple("frame-roundtrip", gen_req, |c| {
        let enc = encode(c);
        match frame::decode(&enc) {
            Ok(Some((Frame::ClassifyReq { model, bits, words, deadline_ms }, consumed))) => {
                if consumed != enc.len() {
                    return Err(format!("consumed {consumed} of {}", enc.len()));
                }
                if model != c.model
                    || bits != c.bits
                    || words != c.words
                    || deadline_ms != c.deadline_ms
                {
                    return Err("decoded frame differs from the encoded one".into());
                }
                Ok(())
            }
            other => Err(format!("expected a complete classify req, got {other:?}")),
        }
    });
}

#[test]
fn decoded_request_scatters_into_packed_bit_exactly() {
    check_simple("frame-into-packed", gen_req, |c| {
        let samples = c.words.len() / frame::words_per_sample(c.bits);
        let packed = frame::request_into_packed(c.bits, &c.words);
        if packed.num_samples() != samples {
            return Err(format!(
                "packed {} samples, request carried {samples}",
                packed.num_samples()
            ));
        }
        let mut want = PackedBatch::with_capacity(c.bits as usize, samples);
        for s in 0..samples {
            want.push_sample(&frame::sample_bits(c.bits, &c.words, s));
        }
        if packed == want {
            Ok(())
        } else {
            Err("word-scatter fast path differs from per-sample push".into())
        }
    });
}

#[test]
fn any_strict_prefix_is_incomplete_never_an_error() {
    check_simple(
        "frame-prefix",
        |g| {
            let enc = encode(&gen_req(g));
            let cut = g.rng.below(enc.len() as u64) as usize;
            (enc, cut)
        },
        |(enc, cut)| match frame::decode(&enc[..*cut]) {
            Ok(None) => Ok(()),
            other => Err(format!("prefix of {cut} bytes gave {other:?}")),
        },
    );
}

/// A valid multi-frame stream plus a random chunking of it into reads.
#[derive(Clone, Debug)]
struct SplitCase {
    stream: Vec<u8>,
    cuts: Vec<usize>,
}

fn gen_split(g: &mut Gen) -> SplitCase {
    let nframes = g.sized_range(1, 5);
    let mut stream = Vec::new();
    for _ in 0..nframes {
        match g.rng.below(5) {
            0 => stream.extend(encode(&gen_req(g))),
            1 => {
                let n = g.sized_range(0, 9);
                let classes: Vec<u16> =
                    (0..n).map(|_| g.rng.next_u32() as u16).collect();
                stream.extend(frame::encode_classify_resp(&classes));
            }
            2 => stream.extend(frame::encode_error("boom")),
            3 => stream.extend(frame::encode_deadline("budget elapsed")),
            _ => stream.extend(frame::encode_overload("queue full")),
        }
    }
    let mut cuts = Vec::new();
    let mut rem = stream.len();
    while rem > 0 {
        let c = 1 + g.rng.below(rem.min(17) as u64) as usize;
        cuts.push(c);
        rem -= c;
    }
    SplitCase { stream, cuts }
}

/// Shrink by merging adjacent read chunks — the stream itself must stay
/// intact (cutting it mid-frame would change the case, not shrink it).
fn shrink_split(c: &SplitCase) -> Vec<SplitCase> {
    let mut out = Vec::new();
    for i in 0..c.cuts.len().saturating_sub(1) {
        let mut cuts = c.cuts.clone();
        let merged = cuts[i] + cuts[i + 1];
        cuts[i] = merged;
        cuts.remove(i + 1);
        out.push(SplitCase { stream: c.stream.clone(), cuts });
    }
    out
}

#[test]
fn any_byte_split_across_reads_decodes_identically() {
    check(
        "frame-split-equivalence",
        &Config::default(),
        gen_split,
        shrink_split,
        |c| {
            // Reference: sequential decode of the whole stream at once.
            let mut expected = Vec::new();
            let mut off = 0;
            while off < c.stream.len() {
                match frame::decode(&c.stream[off..]) {
                    Ok(Some((f, n))) => {
                        expected.push(f);
                        off += n;
                    }
                    other => return Err(format!("reference decode gave {other:?}")),
                }
            }
            // Incremental: feed the chunks through an accumulation buffer
            // exactly the way a connection's read loop does.
            let mut buf: Vec<u8> = Vec::new();
            let mut got = Vec::new();
            let mut fed = 0;
            for &cut in &c.cuts {
                buf.extend_from_slice(&c.stream[fed..fed + cut]);
                fed += cut;
                loop {
                    match frame::decode(&buf) {
                        Ok(Some((f, n))) => {
                            got.push(f);
                            buf.drain(..n);
                        }
                        Ok(None) => break,
                        Err(e) => return Err(format!("incremental decode: {e}")),
                    }
                }
            }
            if !buf.is_empty() {
                return Err(format!("{} bytes left undecoded", buf.len()));
            }
            if got == expected {
                Ok(())
            } else {
                Err(format!(
                    "split decode gave {} frames, reference {}",
                    got.len(),
                    expected.len()
                ))
            }
        },
    );
}

#[test]
fn hostile_length_prefixes_are_rejected_from_the_header_alone() {
    check_simple(
        "frame-oversized-prefix",
        |g| {
            let mut enc = encode(&gen_req(g));
            let excess =
                MAX_FRAME_PAYLOAD as u32 + 1 + g.rng.next_u32() % 1_000_000;
            enc[4..8].copy_from_slice(&excess.to_le_bytes());
            enc.truncate(HEADER_LEN); // the payload must never be needed
            (enc, excess)
        },
        |(buf, excess)| match frame::decode(buf) {
            Err(FrameError::Oversized(n)) if n == *excess => Ok(()),
            other => Err(format!("expected Oversized({excess}), got {other:?}")),
        },
    );
}

#[test]
fn bad_magic_and_bad_version_are_typed_errors() {
    check_simple(
        "frame-bad-magic-version",
        |g| {
            let enc = encode(&gen_req(g));
            (enc, g.rng.next_u32() as u8, g.rng.next_u32() as u8)
        },
        |(enc, magic, version)| {
            if *magic != frame::MAGIC {
                let mut b = enc.clone();
                b[0] = *magic;
                if frame::decode(&b) != Err(FrameError::BadMagic(*magic)) {
                    return Err(format!("magic {magic:#04x} not rejected"));
                }
            }
            if *version != frame::VERSION {
                let mut b = enc.clone();
                b[1] = *version;
                if frame::decode(&b) != Err(FrameError::BadVersion(*version)) {
                    return Err(format!("version {version} not rejected"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn random_corruption_never_panics_or_over_consumes() {
    check_simple(
        "frame-corruption",
        |g| {
            let mut enc = encode(&gen_req(g));
            let flips = g.sized_range(1, 8);
            for _ in 0..flips {
                let i = g.rng.below(enc.len() as u64) as usize;
                enc[i] ^= g.rng.next_u32() as u8; // xor-with-0 is a legal no-op
            }
            enc
        },
        |enc| match frame::decode(enc) {
            Ok(Some((_, consumed))) if consumed > enc.len() => {
                Err(format!("consumed {consumed} past the {}-byte buffer", enc.len()))
            }
            _ => Ok(()), // any verdict is fine; not panicking is the property
        },
    );
}

#[test]
fn arbitrary_byte_soup_never_panics_and_always_terminates() {
    check_simple(
        "frame-byte-soup",
        |g| {
            let n = g.sized_range(0, 64);
            let mut v: Vec<u8> = (0..n).map(|_| g.rng.next_u32() as u8).collect();
            // Half the cases start with the magic byte so the parser gets
            // past the sniff check and into header validation.
            if !v.is_empty() && g.rng.below(2) == 0 {
                v[0] = frame::MAGIC;
            }
            v
        },
        |bytes| {
            let mut buf = bytes.clone();
            loop {
                match frame::decode(&buf) {
                    Ok(Some((_, n))) => {
                        if n == 0 {
                            return Err("zero-byte consume would spin forever".into());
                        }
                        buf.drain(..n);
                    }
                    Ok(None) | Err(_) => return Ok(()),
                }
            }
        },
    );
}
