//! Integration: cross-system equivalence — NullaNet flow vs LogicNets
//! baseline vs exact NN; emitters produce parseable, consistent output.

use nullanet_tiny::baseline::build_logicnets;
use nullanet_tiny::flow::{run_flow, FlowConfig};
use nullanet_tiny::logic::blif::{netlist_to_blif, pipelined_to_blif};
use nullanet_tiny::logic::sim::CompiledNetlist;
use nullanet_tiny::logic::verilog::pipelined_to_verilog;
use nullanet_tiny::nn::model::random_model;
use nullanet_tiny::util::prng::Xoshiro256;

#[test]
fn flow_and_baseline_compute_identical_functions() {
    for seed in [3u64, 17, 99] {
        let m = random_model("eq", 7, &[6, 4, 3], 3, 2, seed);
        let ours = run_flow(&m, &FlowConfig { jobs: 2, ..Default::default() }, None)
            .unwrap();
        let theirs = build_logicnets(&m, 6).unwrap();
        let sa = CompiledNetlist::compile(&ours.circuit.netlist);
        let sb = CompiledNetlist::compile(&theirs.circuit.netlist);
        let mut rng = Xoshiro256::new(seed ^ 0xF0);
        let n_in = m.input_bits();
        let samples: Vec<Vec<bool>> = (0..300)
            .map(|_| (0..n_in).map(|_| rng.bernoulli(0.5)).collect())
            .collect();
        assert_eq!(sa.run_batch(&samples), sb.run_batch(&samples), "seed {seed}");
    }
}

#[test]
fn our_flow_beats_baseline_on_area_for_wide_neurons() {
    // γ·β = 8 > 6: the regime Table I compares (baseline must mux-decompose).
    let m = random_model("area", 10, &[10, 6, 5], 4, 2, 41);
    let ours = run_flow(&m, &FlowConfig { jobs: 2, ..Default::default() }, None).unwrap();
    let theirs = build_logicnets(&m, 6).unwrap();
    assert!(
        ours.circuit.netlist.num_luts() < theirs.circuit.netlist.num_luts(),
        "ours {} vs baseline {}",
        ours.circuit.netlist.num_luts(),
        theirs.circuit.netlist.num_luts()
    );
}

#[test]
fn emitted_blif_is_structurally_sound() {
    let m = random_model("blif", 5, &[4, 3], 2, 1, 7);
    let r = run_flow(&m, &FlowConfig { jobs: 1, ..Default::default() }, None).unwrap();
    let blif = pipelined_to_blif(&r.circuit, "jsc_test");
    assert!(blif.starts_with(".model jsc_test"));
    assert!(blif.ends_with(".end\n"));
    // one .names per LUT + one per output buffer + constants
    let names = blif.matches(".names").count();
    assert!(names >= r.circuit.netlist.num_luts() + r.circuit.netlist.outputs.len());
    // latch count matches the FF counter minus I/O registers
    let latches = blif.matches(".latch").count();
    let ffs = r.circuit.count_ffs();
    let io_regs = m.input_bits() + r.circuit.netlist.outputs.len();
    assert_eq!(latches, ffs - io_regs, "inter-stage latches");

    let comb = netlist_to_blif(&r.circuit.netlist, "comb");
    assert!(comb.contains(".inputs"));
    assert!(!comb.contains(".latch"));
}

#[test]
fn emitted_verilog_is_structurally_sound() {
    let m = random_model("vlog", 5, &[4, 3], 2, 1, 7);
    let r = run_flow(&m, &FlowConfig { jobs: 1, ..Default::default() }, None).unwrap();
    let v = pipelined_to_verilog(&r.circuit, "jsc_test");
    assert!(v.starts_with("module jsc_test"));
    assert!(v.ends_with("endmodule\n"));
    assert!(v.contains("input  wire clk"));
    // every LUT has an assign
    for j in 0..r.circuit.netlist.num_luts() {
        assert!(v.contains(&format!("assign n{j} =")), "missing n{j}");
    }
    // balanced parens (cheap syntax sanity)
    assert_eq!(v.matches('(').count(), v.matches(')').count());
}

#[test]
fn baseline_cost_scales_with_fanin_bits() {
    use nullanet_tiny::baseline::logicnets::lut_cost_per_bit;
    // LogicNets eq. 1 shape: exponential in γ·β − k.
    assert!(lut_cost_per_bit(8, 6) < lut_cost_per_bit(10, 6));
    assert!(lut_cost_per_bit(10, 6) < lut_cost_per_bit(12, 6));
    let m6 = random_model("c6", 8, &[4], 3, 2, 1); // 6-bit neurons
    let m8 = random_model("c8", 8, &[4], 4, 2, 1); // 8-bit neurons
    let b6 = build_logicnets(&m6, 6).unwrap();
    let b8 = build_logicnets(&m8, 6).unwrap();
    assert!(b6.circuit.netlist.num_luts() < b8.circuit.netlist.num_luts());
}

#[test]
fn espresso_ablation_shapes() {
    // A3: espresso on/off and retime on/off — cost relationships that the
    // logic_opt bench reports, asserted here as invariants.
    let m = random_model("abl", 8, &[8, 5], 3, 2, 23);
    let full = run_flow(&m, &FlowConfig { jobs: 2, ..Default::default() }, None).unwrap();
    let no_esp = run_flow(
        &m,
        &FlowConfig { use_espresso: false, jobs: 2, ..Default::default() },
        None,
    )
    .unwrap();
    let no_ret = run_flow(
        &m,
        &FlowConfig { retime: false, jobs: 2, ..Default::default() },
        None,
    )
    .unwrap();
    assert!(full.total_cubes_after <= no_esp.total_cubes_after);
    assert!(
        full.circuit.stats().max_stage_depth <= no_ret.circuit.stats().max_stage_depth
    );
}
