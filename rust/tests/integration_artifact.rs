//! Integration: persistent compiled-circuit artifacts — serialize → parse →
//! `CompiledNetlist` bit-exactness across random models, fingerprint
//! rejection, and the cold-start contract (compile once, serve from the
//! loaded artifact with no re-synthesis).

use std::time::Duration;

use nullanet_tiny::coordinator::{BatchPolicy, Policy, RouterBuilder};
use nullanet_tiny::flow::artifact::{
    circuit_from_json, circuit_to_json, load_circuit, model_fingerprint, save_circuit,
    ArtifactError,
};
use nullanet_tiny::flow::{run_flow, FlowConfig};
use nullanet_tiny::logic::sim::CompiledNetlist;
use nullanet_tiny::nn::model::random_model;
use nullanet_tiny::util::json::Json;
use nullanet_tiny::util::prng::Xoshiro256;
use nullanet_tiny::util::proptest::{check, Config, Gen};

/// Random model shape for the round-trip property.
#[derive(Clone, Debug)]
struct Shape {
    features: usize,
    widths: Vec<usize>,
    fanin: usize,
    bits: usize,
    seed: u64,
}

fn gen_shape(g: &mut Gen) -> Shape {
    let layers = g.sized_range(1, 3);
    Shape {
        features: g.sized_range(3, 8),
        widths: (0..layers).map(|_| g.sized_range(2, 5)).collect(),
        fanin: g.sized_range(1, 3),
        bits: g.sized_range(1, 2),
        seed: g.rng.next_u64(),
    }
}

#[test]
fn artifact_roundtrip_is_bit_exact_across_random_models() {
    // Each case runs a full synthesis flow, so keep the case count modest.
    check(
        "artifact-roundtrip",
        &Config { cases: 6, seed: 0xA57_1FAC7, max_shrink_steps: 0 },
        gen_shape,
        |_| Vec::new(),
        |s| {
            let m = random_model("prop", s.features, &s.widths, s.fanin, s.bits, s.seed);
            let cfg = FlowConfig { jobs: 1, verify: false, ..Default::default() };
            let r = run_flow(&m, &cfg, None).map_err(|e| e.to_string())?;
            let text = circuit_to_json(&r.circuit, &m).to_pretty_string();
            let parsed = Json::parse(&text).map_err(|e| e.to_string())?;
            let back = circuit_from_json(&parsed, &m).map_err(|e| e.to_string())?;
            if back.stage_of_lut != r.circuit.stage_of_lut {
                return Err("stage assignment changed in round-trip".into());
            }
            if back.num_stages != r.circuit.num_stages {
                return Err("stage count changed in round-trip".into());
            }
            // The reloaded circuit must compile to a bit-identical simulator:
            // compare packed 64-lane evaluations on random words.
            let a = CompiledNetlist::compile(&r.circuit.netlist);
            let b = CompiledNetlist::compile(&back.netlist);
            let mut sa = a.make_scratch();
            let mut sb = b.make_scratch();
            let mut rng = Xoshiro256::new(s.seed ^ 0xBEEF);
            for round in 0..32 {
                let inputs: Vec<u64> =
                    (0..a.num_inputs()).map(|_| rng.next_u64()).collect();
                let mut oa = vec![0u64; a.num_outputs()];
                let mut ob = vec![0u64; b.num_outputs()];
                a.run_words(&mut sa, &inputs, &mut oa);
                b.run_words(&mut sb, &inputs, &mut ob);
                if oa != ob {
                    return Err(format!("outputs diverge on round {round}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn fingerprint_mismatch_is_rejected_across_files() {
    let a = random_model("fpa", 5, &[4, 3], 2, 1, 1);
    let b = random_model("fpb", 5, &[4, 3], 2, 1, 2);
    assert_ne!(model_fingerprint(&a), model_fingerprint(&b));

    let path = "/tmp/nnt_fp_mismatch.circuit.json";
    let r = run_flow(&a, &FlowConfig { jobs: 1, ..Default::default() }, None).unwrap();
    save_circuit(path, &r.circuit, &a).unwrap();
    let err = load_circuit(path, &b).unwrap_err();
    assert!(
        matches!(err, ArtifactError::FingerprintMismatch { .. }),
        "want typed fingerprint rejection, got {err}"
    );
    // The matching model still loads.
    assert!(load_circuit(path, &a).is_ok());
    std::fs::remove_file(path).ok();
}

#[test]
fn compile_then_load_serves_bit_exact_without_resynthesis() {
    let m = random_model("cold", 6, &[5, 3], 2, 1, 77);
    let path = "/tmp/nnt_cold_start.circuit.json";
    {
        let r = run_flow(&m, &FlowConfig { jobs: 1, ..Default::default() }, None).unwrap();
        save_circuit(path, &r.circuit, &m).unwrap();
    }
    // Cold start: everything below runs from the artifact file — no
    // `run_flow` call on this path.
    let circuit = load_circuit(path, &m).unwrap();
    let router = RouterBuilder::new(m.clone())
        .circuit(circuit.netlist)
        .engine(Policy::Logic)
        .batch_policy(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        })
        .workers(2)
        .build()
        .unwrap();
    let mut rxs = Vec::new();
    let mut want = Vec::new();
    for i in 0..40 {
        let x: Vec<f64> = (0..6).map(|j| ((i * 7 + j) as f64 * 0.23).sin()).collect();
        want.push(nullanet_tiny::nn::eval::classify(&m, &x));
        rxs.push(router.submit(x));
    }
    for (rx, w) in rxs.into_iter().zip(want) {
        let reply = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(reply.class, w, "artifact-served reply must match the NN");
        assert_eq!(reply.engine, "logic");
    }
    router.shutdown();
    std::fs::remove_file(path).ok();
}
