//! Integration: the full NullaNet Tiny flow across model shapes and
//! configuration axes, checked end-to-end against the exact NN evaluation.

use nullanet_tiny::flow::{circuit_accuracy, run_flow, FlowConfig};
use nullanet_tiny::fpga::timing::TimingModel;
use nullanet_tiny::logic::sim::CompiledNetlist;
use nullanet_tiny::nn::eval::{bits_to_codes, codes_to_bits, forward_codes, quantize_input};
use nullanet_tiny::nn::model::{random_model, Model};

fn exhaustive_check(model: &Model, circuit: &nullanet_tiny::logic::netlist::PipelinedCircuit) {
    let in_bits = model.input_bits();
    assert!(in_bits <= 14, "exhaustive check limited");
    let sim = CompiledNetlist::compile(&circuit.netlist);
    let out_b = model.layers.last().unwrap().act.bits;
    let in_b = model.input_quant.bits;
    for m in 0..1u64 << in_bits {
        let codes: Vec<usize> = (0..model.input_features)
            .map(|i| ((m >> (i * in_b)) & ((1 << in_b) - 1)) as usize)
            .collect();
        let want = forward_codes(model, &codes).codes.last().unwrap().clone();
        let bools: Vec<bool> = (0..in_bits).map(|i| (m >> i) & 1 == 1).collect();
        let got = bits_to_codes(&sim.run_batch(&[bools]).pop().unwrap(), out_b);
        assert_eq!(got, want, "m={m}");
    }
}

#[test]
fn flow_exhaustive_on_various_shapes() {
    for (feats, widths, fanin, bits, seed) in [
        (5usize, vec![4usize, 3], 2usize, 1usize, 1u64),
        (6, vec![8, 4, 3], 3, 2, 2),
        (4, vec![10, 5], 4, 2, 3),
        (7, vec![3], 2, 2, 4),
    ] {
        let m = random_model("shape", feats, &widths, fanin, bits, seed);
        if m.input_bits() > 14 {
            continue;
        }
        let r = run_flow(&m, &FlowConfig { jobs: 2, ..Default::default() }, None).unwrap();
        exhaustive_check(&m, &r.circuit);
    }
}

#[test]
fn config_matrix_all_equivalent() {
    let m = random_model("cfg", 6, &[5, 4, 3], 3, 2, 77);
    let mut baseline_preds: Option<Vec<usize>> = None;
    let xs: Vec<Vec<f64>> = (0..100)
        .map(|i| (0..6).map(|j| ((i * 3 + j) as f64 * 0.29).sin() * 2.0).collect())
        .collect();
    for espresso in [true, false] {
        for retime in [true, false] {
            for area in [true, false] {
                let cfg = FlowConfig {
                    use_espresso: espresso,
                    retime,
                    map_for_area: area,
                    jobs: 1,
                    ..Default::default()
                };
                let r = run_flow(&m, &cfg, None).unwrap();
                let sim = CompiledNetlist::compile(&r.circuit.netlist);
                let preds =
                    nullanet_tiny::flow::build::classify_batch(&m, &sim, &xs);
                match &baseline_preds {
                    None => baseline_preds = Some(preds),
                    Some(b) => assert_eq!(&preds, b, "espresso={espresso} retime={retime} area={area}"),
                }
            }
        }
    }
}

#[test]
fn trained_artifacts_end_to_end_if_present() {
    // Uses the real trained model when `make artifacts` has run; skips
    // silently otherwise so `cargo test` works on a fresh checkout.
    let path = "artifacts/jsc-s.model.json";
    if !std::path::Path::new(path).exists() {
        eprintln!("skipping: {path} not built");
        return;
    }
    let model = Model::load(path).unwrap();
    let r = run_flow(&model, &FlowConfig::default(), None).unwrap();
    let stats = r.circuit.stats();
    assert!(stats.luts > 0 && stats.luts < 5000, "JSC-S LUTs: {}", stats.luts);
    assert_eq!(stats.latency_cycles, 3, "three layers → three stages");
    // fmax must land in the paper's JSC-S band with default calibration
    let fmax = TimingModel::vu9p().fmax_mhz(stats.max_stage_depth);
    assert!(fmax > 500.0, "fmax {fmax}");
    if std::path::Path::new("artifacts/jsc_test.bin").exists() {
        let test = nullanet_tiny::data::Dataset::load("artifacts/jsc_test.bin").unwrap();
        let acc = circuit_accuracy(&model, &r.circuit, &test.xs, &test.ys);
        assert!(acc > 0.60, "trained JSC-S logic accuracy {acc}");
    }
}

#[test]
fn dc_from_data_preserves_observed_behaviour_and_saves_area() {
    let m = random_model("dc", 6, &[6, 4], 3, 2, 5);
    let xs: Vec<Vec<f64>> = (0..150)
        .map(|i| (0..6).map(|j| ((i * 7 + j) as f64 * 0.23).cos() * 1.5).collect())
        .collect();
    let full = run_flow(
        &m,
        &FlowConfig { jobs: 1, ..Default::default() },
        None,
    )
    .unwrap();
    let dc = run_flow(
        &m,
        &FlowConfig { dc_from_data: true, verify: false, jobs: 1, ..Default::default() },
        Some(&xs),
    )
    .unwrap();
    // Observed inputs classify identically.
    let sa = CompiledNetlist::compile(&full.circuit.netlist);
    let sb = CompiledNetlist::compile(&dc.circuit.netlist);
    let pa = nullanet_tiny::flow::build::classify_batch(&m, &sa, &xs);
    let pb = nullanet_tiny::flow::build::classify_batch(&m, &sb, &xs);
    assert_eq!(pa, pb);
    // DC flow should not use more cubes.
    assert!(dc.total_cubes_after <= full.total_cubes_after);
}

#[test]
fn input_codes_roundtrip_through_circuit_wiring() {
    // The wire-order contract: codes_to_bits ∘ bits_to_codes = id and the
    // circuit's input ordering matches quantize_input.
    let m = random_model("wire", 5, &[4, 3], 2, 2, 9);
    let r = run_flow(&m, &FlowConfig { jobs: 1, ..Default::default() }, None).unwrap();
    let sim = CompiledNetlist::compile(&r.circuit.netlist);
    for s in 0..40u64 {
        let x: Vec<f64> = (0..5).map(|i| ((s + i as u64) as f64 * 0.41).sin() * 2.0).collect();
        let codes = quantize_input(&m, &x);
        let bits = codes_to_bits(&codes, m.input_quant.bits);
        assert_eq!(bits_to_codes(&bits, m.input_quant.bits), codes);
        let out = sim.run_batch(&[bits]).pop().unwrap();
        let got = bits_to_codes(&out, m.layers.last().unwrap().act.bits);
        let want = forward_codes(&m, &codes).codes.last().unwrap().clone();
        assert_eq!(got, want);
    }
}
