//! Integration: the PJRT runtime path — AOT HLO artifacts load, execute,
//! and agree with the Rust-side quantized evaluation.
//!
//! These tests need `make artifacts` to have run; on a fresh checkout they
//! skip with a message (keeps `cargo test` green pre-build).

use nullanet_tiny::data::Dataset;
use nullanet_tiny::nn::eval;
use nullanet_tiny::nn::model::Model;
use nullanet_tiny::runtime::PjrtEngine;

fn artifacts_ready(arch: &str) -> bool {
    std::path::Path::new(&format!("artifacts/{arch}.hlo.txt")).exists()
        && std::path::Path::new(&format!("artifacts/{arch}.model.json")).exists()
}

#[test]
fn pjrt_loads_and_classifies_jsc_s() {
    if !artifacts_ready("jsc-s") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let model = Model::load("artifacts/jsc-s.model.json").unwrap();
    let out_w = model.layers.last().unwrap().out_width;
    let engine =
        PjrtEngine::load("artifacts/jsc-s.hlo.txt", 64, model.input_features, out_w)
            .unwrap();
    assert!(engine.platform().contains("cpu") || engine.platform().contains("Host"));

    // Agreement with the exact integer evaluation on real test data. The
    // PJRT path computes in f32, the Rust gold path in f64 over exported
    // tables: classifications must agree on ≳99% of samples (ties at
    // quantizer thresholds account for the rest).
    let test = Dataset::load("artifacts/jsc_test.bin").unwrap();
    let n = 1024.min(test.len());
    let xs = &test.xs[..n];
    let pjrt_pred = engine.classify_all(xs, model.num_classes).unwrap();
    let rust_pred: Vec<usize> = xs.iter().map(|x| eval::classify(&model, x)).collect();
    let agree = pjrt_pred
        .iter()
        .zip(&rust_pred)
        .filter(|(a, b)| a == b)
        .count() as f64
        / n as f64;
    assert!(agree > 0.99, "PJRT vs Rust agreement {agree}");
}

#[test]
fn pjrt_batch_padding() {
    if !artifacts_ready("jsc-s") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let model = Model::load("artifacts/jsc-s.model.json").unwrap();
    let out_w = model.layers.last().unwrap().out_width;
    let engine =
        PjrtEngine::load("artifacts/jsc-s.hlo.txt", 64, model.input_features, out_w)
            .unwrap();
    // batches of 1, 63, 64 and 65 (the last via classify_all chunking)
    let test = Dataset::load("artifacts/jsc_test.bin").unwrap();
    for n in [1usize, 63, 64] {
        let preds = engine.classify(&test.xs[..n], model.num_classes).unwrap();
        assert_eq!(preds.len(), n);
    }
    let preds = engine.classify_all(&test.xs[..65], model.num_classes).unwrap();
    assert_eq!(preds.len(), 65);
    // padding must not change results: sample 0 alone == sample 0 in batch
    let solo = engine.classify(&test.xs[..1], model.num_classes).unwrap();
    let batch = engine.classify(&test.xs[..64], model.num_classes).unwrap();
    assert_eq!(solo[0], batch[0]);
}

#[test]
fn pjrt_rejects_bad_input() {
    if !artifacts_ready("jsc-s") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let model = Model::load("artifacts/jsc-s.model.json").unwrap();
    let out_w = model.layers.last().unwrap().out_width;
    let engine =
        PjrtEngine::load("artifacts/jsc-s.hlo.txt", 64, model.input_features, out_w)
            .unwrap();
    // wrong feature count
    assert!(engine.infer(&[vec![0.0; 3]]).is_err());
    // oversize batch
    let too_many = vec![vec![0.0; model.input_features]; 65];
    assert!(engine.infer(&too_many).is_err());
    // empty is fine
    assert!(engine.infer(&[]).unwrap().is_empty());
}

#[test]
fn missing_artifact_is_a_clean_error() {
    let r = PjrtEngine::load("artifacts/does-not-exist.hlo.txt", 64, 16, 5);
    assert!(r.is_err());
}

#[test]
fn all_three_arch_artifacts_load() {
    for arch in ["jsc-s", "jsc-m", "jsc-l"] {
        if !artifacts_ready(arch) {
            eprintln!("skipping {arch}: artifacts not built");
            continue;
        }
        let model = Model::load(&format!("artifacts/{arch}.model.json")).unwrap();
        let out_w = model.layers.last().unwrap().out_width;
        let engine = PjrtEngine::load(
            &format!("artifacts/{arch}.hlo.txt"),
            64,
            model.input_features,
            out_w,
        )
        .unwrap();
        let xs = vec![vec![0.1; model.input_features]; 4];
        let out = engine.infer(&xs).unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(out[0].len(), out_w);
        assert!(out[0].iter().all(|v| v.is_finite()));
    }
}
