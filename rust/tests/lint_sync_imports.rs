//! Source lint: every lock in the crate must come from `util::sync`.
//!
//! The model checker (ISSUE 7) can only explore interleavings of code that
//! routes its synchronization through the shim layer, and the lock-order
//! analysis only sees named shim locks. A direct `std::sync::Mutex`,
//! `Condvar`, or `RwLock` anywhere else silently escapes both, so this test
//! walks the source tree and fails on any such use outside the two files
//! that implement the shim itself (`util/sync.rs`, `util/mc.rs`).
//!
//! Atomics, `Arc`, `mpsc`, and `std::thread` remain fine to use directly in
//! code that never crosses a shim API boundary (the shim re-exports them
//! for code that does).

use std::fmt::Write as _;
use std::fs;
use std::path::{Path, PathBuf};

const FORBIDDEN: [&str; 3] = ["Mutex", "Condvar", "RwLock"];

/// Files that are allowed to touch `std::sync` lock primitives directly:
/// the shim and the scheduler underneath it (which must not recurse into
/// itself), plus this lint (its docs name the forbidden paths).
const EXEMPT: [&str; 3] = ["util/sync.rs", "util/mc.rs", "lint_sync_imports.rs"];

fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) {
    for entry in fs::read_dir(dir).unwrap_or_else(|e| panic!("read_dir {dir:?}: {e}")) {
        let path = entry.unwrap().path();
        if path.is_dir() {
            rust_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// Scan one line (comments already stripped) for `std::sync::<Lock>` or a
/// brace import `std::sync::{..., <Lock>, ...}`. Brace groups in practice
/// fit on one line in this codebase; a multi-line group would still be
/// caught when the lock name follows `std::sync::{` on its opening line,
/// and rustfmt keeps imports single-line here.
fn violation(line: &str) -> Option<&'static str> {
    let mut rest = line;
    while let Some(pos) = rest.find("std::sync::") {
        let tail = &rest[pos + "std::sync::".len()..];
        if let Some(group) = tail.strip_prefix('{') {
            let group = group.split('}').next().unwrap_or(group);
            for name in FORBIDDEN {
                // Token match: `Mutex` but not `MutexGuard` or `StdMutex`.
                if group
                    .split(|c: char| !c.is_alphanumeric() && c != '_')
                    .any(|tok| tok == name)
                {
                    return Some(name);
                }
            }
        } else {
            for name in FORBIDDEN {
                if tail.starts_with(name)
                    && !tail[name.len()..]
                        .chars()
                        .next()
                        .is_some_and(|c| c.is_alphanumeric() || c == '_')
                {
                    return Some(name);
                }
            }
        }
        rest = tail;
    }
    None
}

#[test]
fn no_direct_std_sync_locks_outside_the_shim() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let mut files = Vec::new();
    rust_files(&root.join("rust/src"), &mut files);
    rust_files(&root.join("rust/tests"), &mut files);
    files.sort();

    let mut report = String::new();
    for path in &files {
        let rel = path.strip_prefix(root).unwrap_or(path).to_string_lossy().replace('\\', "/");
        if EXEMPT.iter().any(|e| rel.ends_with(e)) {
            continue;
        }
        let text = fs::read_to_string(path).unwrap_or_else(|e| panic!("read {rel}: {e}"));
        for (i, raw) in text.lines().enumerate() {
            let code = raw.split("//").next().unwrap_or(raw);
            if let Some(name) = violation(code) {
                writeln!(report, "  {rel}:{}: direct std::sync::{name}", i + 1).unwrap();
            }
        }
    }
    assert!(
        report.is_empty(),
        "direct std::sync lock primitives outside util/sync.rs — \
         route them through crate::util::sync so the model checker and \
         lock-order analysis can see them:\n{report}"
    );
}
