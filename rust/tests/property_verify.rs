//! Property-based tests for the formal-verification stack: the CDCL SAT
//! solver (`util::sat`) against brute-force enumeration, and the SAT-based
//! equivalence checker (`logic::cec`) against both the exhaustive
//! differential checker and deliberately mutated netlists.

use nullanet_tiny::logic::cec::{check_netlists, CecResult};
use nullanet_tiny::logic::netlist::{LutNetlist, Sig};
use nullanet_tiny::logic::opt::optimize;
use nullanet_tiny::logic::truthtable::TruthTable;
use nullanet_tiny::logic::verify::exhaustive_netlists;
use nullanet_tiny::util::proptest::{check_simple, Gen};
use nullanet_tiny::util::sat::{Lit, SatResult, Solver};

/// A random CNF formula: (num_vars, clauses), each clause a list of
/// (variable, negated) pairs. Tautologies, duplicate literals, and repeated
/// clauses are all allowed — the solver must handle them.
type Cnf = (usize, Vec<Vec<(usize, bool)>>);

fn gen_cnf(g: &mut Gen) -> Cnf {
    let nvars = g.sized_range(1, 12);
    let nclauses = g.sized_range(1, 40);
    let clauses = (0..nclauses)
        .map(|_| {
            let len = g.sized_range(1, 4);
            (0..len)
                .map(|_| (g.rng.below(nvars as u64) as usize, g.rng.bernoulli(0.5)))
                .collect()
        })
        .collect();
    (nvars, clauses)
}

/// Evaluate a CNF under assignment `m` (bit `v` of `m` = variable `v`).
fn cnf_eval(clauses: &[Vec<(usize, bool)>], m: u64) -> bool {
    clauses
        .iter()
        .all(|c| c.iter().any(|&(v, neg)| ((m >> v) & 1 == 1) != neg))
}

#[test]
fn sat_verdict_matches_brute_force() {
    check_simple(
        "sat-vs-brute-force",
        gen_cnf,
        |(nvars, clauses)| {
            let mut s = Solver::new();
            for _ in 0..*nvars {
                s.new_var();
            }
            for c in clauses {
                let lits: Vec<Lit> = c
                    .iter()
                    .map(|&(v, neg)| {
                        if neg {
                            Lit::neg(v as u32)
                        } else {
                            Lit::pos(v as u32)
                        }
                    })
                    .collect();
                s.add_clause(&lits);
            }
            let brute_sat = (0..1u64 << nvars).any(|m| cnf_eval(clauses, m));
            match s.solve() {
                SatResult::Unsat => {
                    if brute_sat {
                        return Err("solver says UNSAT but a model exists".into());
                    }
                }
                SatResult::Sat(model) => {
                    if !brute_sat {
                        return Err("solver says SAT but no model exists".into());
                    }
                    let m: u64 = model
                        .iter()
                        .take(*nvars)
                        .enumerate()
                        .map(|(v, &b)| (b as u64) << v)
                        .sum();
                    if !cnf_eval(clauses, m) {
                        return Err("solver model does not satisfy the formula".into());
                    }
                }
            }
            Ok(())
        },
    );
}

/// Random LUT netlist in the style the mapper emits: arities 0–6, inputs
/// drawn with replacement, occasional constant inputs, duplicated LUTs and
/// dead logic (optimizer fodder). ≤ 10 primary inputs so the exhaustive
/// checker can cross-examine every CEC verdict.
fn gen_netlist(g: &mut Gen) -> LutNetlist {
    let nin = g.sized_range(1, 10);
    let nluts = g.sized_range(1, 20);
    let mut nl = LutNetlist::new(nin);
    for j in 0..nluts {
        let navail = nin + j;
        if j > 0 && g.rng.bernoulli(0.15) {
            let src = g.rng.below(j as u64) as usize;
            let (inputs, table) =
                (nl.luts[src].inputs.clone(), nl.luts[src].table.clone());
            nl.add_lut(inputs, table);
            continue;
        }
        let k = g.rng.below(7) as usize;
        let inputs: Vec<Sig> = (0..k)
            .map(|_| {
                if g.rng.bernoulli(0.1) {
                    return Sig::Const(g.rng.bernoulli(0.5));
                }
                let pick = g.rng.below(navail as u64) as usize;
                if pick < nin {
                    Sig::Input(pick as u32)
                } else {
                    Sig::Lut((pick - nin) as u32)
                }
            })
            .collect();
        let tt = TruthTable::from_fn(k, |_| g.rng.bernoulli(0.5));
        nl.add_lut(inputs, tt);
    }
    for j in 0..nluts.min(4) {
        nl.add_output(Sig::Lut(j as u32), j % 2 == 1);
    }
    nl.add_output(Sig::Input(0), true);
    nl.add_output(Sig::Const(true), false);
    nl
}

#[test]
fn optimizer_output_is_sat_proven_equivalent() {
    // The acceptance property of the formal checker: every `opt::optimize`
    // output must be *proven* (not sampled) equivalent to its input, and
    // the SAT verdict must agree with exhaustive enumeration.
    check_simple(
        "cec-optimizer",
        gen_netlist,
        |nl| {
            let (opt_nl, _) = optimize(nl);
            let cec = check_netlists(nl, &opt_nl).map_err(|e| e.to_string())?;
            if !cec.is_equivalent() {
                return Err(format!("optimizer broke equivalence: {cec:?}"));
            }
            let brute = exhaustive_netlists(nl, &opt_nl).map_err(|e| e.to_string())?;
            if !brute.is_equivalent() {
                return Err("exhaustive disagrees with the SAT proof".into());
            }
            Ok(())
        },
    );
}

#[test]
fn cec_verdict_matches_exhaustive_on_mutated_netlists() {
    // Flip one truth-table bit in a clone: usually inequivalent, but a flip
    // in a dead cone (or one masked downstream) keeps the functions equal —
    // so the property is *agreement* with exhaustive enumeration, plus a
    // genuine witness whenever the checker refutes.
    check_simple(
        "cec-mutations",
        |g| {
            let nl = gen_netlist(g);
            let lut = g.rng.below(nl.luts.len() as u64) as usize;
            let rows = 1u64 << nl.luts[lut].table.nvars();
            let row = g.rng.below(rows) as usize;
            (nl, lut, row)
        },
        |(nl, lut, row)| {
            let mut mutated = nl.clone();
            let mut t = mutated.luts[*lut].table.clone();
            t.set_bit(*row, !t.eval(*row as u64));
            mutated.luts[*lut].table = t;

            let cec = check_netlists(nl, &mutated).map_err(|e| e.to_string())?;
            let brute = exhaustive_netlists(nl, &mutated).map_err(|e| e.to_string())?;
            if cec.is_equivalent() != brute.is_equivalent() {
                return Err(format!(
                    "SAT says {cec:?} but exhaustive says {brute:?}"
                ));
            }
            if let CecResult::Inequivalent { assignment, output } = cec {
                if assignment.len() != nl.num_inputs {
                    return Err("witness width != num_inputs".into());
                }
                let bits: u64 = assignment
                    .iter()
                    .enumerate()
                    .map(|(i, &b)| (b as u64) << i)
                    .sum();
                let ga = nl.eval(bits);
                let gb = mutated.eval(bits);
                if ga[output] == gb[output] {
                    return Err(format!(
                        "witness {bits:#x} does not distinguish output {output}"
                    ));
                }
            }
            Ok(())
        },
    );
}
