//! Failure-injection and robustness tests for the flow + model loader.

use nullanet_tiny::flow::{run_flow, FlowConfig};
use nullanet_tiny::nn::model::{random_model, Model, Quantizer};
use nullanet_tiny::util::json::Json;

#[test]
fn loader_rejects_tampered_models() {
    let m = random_model("tamper", 5, &[4, 3], 2, 1, 3);
    let good = m.to_json().to_string();

    // Valid round trip first.
    assert!(Model::from_json(&Json::parse(&good).unwrap()).is_ok());

    // Remove a required field.
    let j = Json::parse(&good).unwrap();
    if let Json::Obj(mut o) = j {
        o.remove("input_quant");
        let bad = Json::Obj(o).to_string();
        assert!(Model::from_json(&Json::parse(&bad).unwrap()).is_err());
    } else {
        panic!("model json must be an object");
    }

    // Corrupt quantizer (unsorted levels).
    let mut m2 = m.clone();
    m2.input_quant = Quantizer { bits: 1, levels: vec![1.0, -1.0], thresholds: vec![0.0] };
    let bad = m2.to_json().to_string();
    assert!(Model::from_json(&Json::parse(&bad).unwrap()).is_err());

    // Mask index out of range.
    let mut m3 = m.clone();
    m3.layers[0].mask[0] = vec![999];
    assert!(Model::from_json(&Json::parse(&m3.to_json().to_string()).unwrap()).is_err());
}

#[test]
fn flow_fails_cleanly_on_invalid_model() {
    let mut m = random_model("inv", 5, &[4, 3], 2, 1, 3);
    m.layers[1].in_width = 99;
    let err = match run_flow(&m, &FlowConfig::default(), None) {
        Err(e) => e,
        Ok(_) => panic!("invalid model must not synthesize"),
    };
    assert!(
        matches!(err, nullanet_tiny::error::NnError::Flow(_)),
        "must be a typed flow error: {err}"
    );
    assert!(err.to_string().contains("in_width"), "{err}");
}

#[test]
fn dc_mode_without_traces_errors() {
    let m = random_model("nodc", 5, &[4, 3], 2, 1, 3);
    let cfg = FlowConfig { dc_from_data: true, ..Default::default() };
    let err = match run_flow(&m, &cfg, None) {
        Err(e) => e,
        Ok(_) => panic!("dc mode without traces must fail"),
    };
    assert!(err.to_string().contains("training inputs"), "{err}");
}

#[test]
fn single_layer_and_single_neuron_models() {
    // Degenerate shapes must work: 1 layer, 1 neuron, fanin 1.
    for (widths, fanin, bits) in [(vec![1usize], 1usize, 1usize), (vec![2], 2, 2), (vec![5], 1, 2)]
    {
        let m = random_model("deg", 4, &widths, fanin, bits, 5);
        let r = run_flow(&m, &FlowConfig { jobs: 1, ..Default::default() }, None).unwrap();
        assert_eq!(r.circuit.num_stages, 1);
        assert!(r.circuit.check_stages().is_ok());
    }
}

#[test]
fn constant_neuron_collapses_to_no_logic() {
    // A neuron whose output never changes must synthesize to constant(s),
    // not LUTs. Build a model with huge positive bias → PACT saturates high.
    let mut m = random_model("const", 4, &[2], 2, 1, 7);
    m.layers[0].bias = vec![1e9, -1e9];
    let r = run_flow(&m, &FlowConfig { jobs: 1, ..Default::default() }, None).unwrap();
    // Both neurons constant → the whole netlist should carry ≈ 0 LUTs.
    assert!(
        r.circuit.netlist.num_luts() == 0,
        "constant neurons must cost nothing, got {} LUTs",
        r.circuit.netlist.num_luts()
    );
}

#[test]
fn dataset_loader_rejects_garbage_files() {
    use nullanet_tiny::data::Dataset;
    let path = "/tmp/nnt_garbage.bin";
    std::fs::write(path, b"this is not a dataset").unwrap();
    assert!(Dataset::load(path).is_err());
    std::fs::remove_file(path).ok();
    assert!(Dataset::load("/tmp/does_not_exist_nnt.bin").is_err());
}
