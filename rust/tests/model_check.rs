//! Exhaustive concurrency models for the serving stack, driven by the
//! in-crate deterministic model checker ([`nullanet_tiny::util::mc`]).
//!
//! Build with `RUSTFLAGS="--cfg nnt_model_check" cargo test --test
//! model_check` to route every `util::sync` primitive through the
//! cooperative scheduler; the checker then explores thread interleavings of
//! each protocol below by DFS with preemption bounding, and prints a
//! replayable `mc1:…` schedule seed on any failure.
//!
//! Under a normal build the shim is a zero-cost `std::sync` re-export and
//! only the smoke test below compiles, so tier-1 wall-clock cost is nil.
//!
//! The four models (ISSUE 7):
//! 1. batcher close-flush vs concurrent submit — every accepted request is
//!    flushed, every rejected one is handed back, none is stranded;
//! 2. registry hot-swap drain vs a racing classify — the in-flight reply
//!    survives the swap and is bit-exact;
//! 3. thread-pool shutdown — no lost wakeup parks a worker forever, all
//!    queued jobs run;
//! 4. `ShardRunner` disjoint-range `SendPtr` writes — the sharded result
//!    equals the single-threaded reference under every schedule.
//!
//! Two more (ISSUE 8), modeling the serving front end's shutdown paths:
//! 5. the blocking server's connection-table handshake — a registering
//!    connection is either half-closed by the shutdown walk or observes
//!    the stop flag itself, never neither (which would park its blocking
//!    read forever);
//! 6. event-loop shutdown vs a racing dispatcher reply — the loop always
//!    terminates and the reply is delivered exactly once or left visibly
//!    queued (abandoned with the connection), never silently lost while
//!    the loop still runs.

#[cfg(not(nnt_model_check))]
#[test]
fn model_checker_is_dormant_without_the_cfg() {
    // The shim routes straight to std; the checker only engages under
    // `--cfg nnt_model_check` (see the CI `model-check` job).
    assert!(!nullanet_tiny::util::mc::active());
}

#[cfg(nnt_model_check)]
mod models {
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    use nullanet_tiny::coordinator::batcher::{BatchPolicy, Batcher, Reply, Request};
    use nullanet_tiny::coordinator::{
        ModelRegistry, Policy, RegistryConfig, Router, RouterBuilder,
    };
    use nullanet_tiny::flow::{run_flow, FlowConfig};
    use nullanet_tiny::logic::netlist::LutNetlist;
    use nullanet_tiny::logic::sim::CompiledNetlist;
    use nullanet_tiny::nn::model::{random_model, Model};
    use nullanet_tiny::util::bitvec::{BitVec, PackedBatch};
    use nullanet_tiny::util::mc;
    use nullanet_tiny::util::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use nullanet_tiny::util::sync::{mpsc, thread, Condvar, Mutex};
    use nullanet_tiny::util::threadpool::ThreadPool;

    /// An hour: the age-flush path must never fire inside a model run
    /// (model time only advances when nothing else is runnable, so a
    /// wall-clock-dependent flush would be schedule noise, not protocol).
    const NEVER: Duration = Duration::from_secs(3600);

    const BITS: usize = 3;

    fn request(pattern: usize) -> (Request, mpsc::Receiver<Reply>) {
        let (tx, rx) = mpsc::channel();
        let bits = BitVec::from_bools((0..BITS).map(|i| (pattern >> i) & 1 == 1));
        (
            Request {
                bits,
                features: None,
                enqueued: Instant::now(),
                reply: tx,
                notify: None,
            },
            rx,
        )
    }

    /// Model 1: two submitters race a `close()` while a dispatcher drains.
    /// Invariant: flushed + rejected == submitted — a request is either
    /// batched (reply side alive) or handed back, never silently stranded
    /// in a queue no dispatcher will ever drain.
    #[test]
    fn batcher_close_flush_vs_concurrent_submit() {
        let cfg = mc::Config::default();
        mc::check(cfg, || {
            let b = Arc::new(Batcher::new(
                BatchPolicy { max_batch: 2, max_wait: NEVER, ..Default::default() },
                BITS,
            ));
            let flushed = Arc::new(AtomicUsize::new(0));
            let rejected = Arc::new(AtomicUsize::new(0));

            let bd = Arc::clone(&b);
            let fd = Arc::clone(&flushed);
            let dispatcher = thread::spawn(move || {
                while let Some(batch) = bd.next_batch() {
                    fd.fetch_add(batch.requests.len(), Ordering::SeqCst);
                }
            });

            let mut submitters = Vec::new();
            for p in 0..2usize {
                let bs = Arc::clone(&b);
                let rj = Arc::clone(&rejected);
                submitters.push(thread::spawn(move || {
                    let (req, _rx) = request(p);
                    if bs.submit(req).is_err() {
                        rj.fetch_add(1, Ordering::SeqCst);
                    }
                }));
            }
            let bc = Arc::clone(&b);
            let closer = thread::spawn(move || bc.close());

            for s in submitters {
                s.join().unwrap();
            }
            closer.join().unwrap();
            dispatcher.join().unwrap();

            let f = flushed.load(Ordering::SeqCst);
            let r = rejected.load(Ordering::SeqCst);
            assert_eq!(f + r, 2, "flushed {f} + rejected {r} != submitted 2");
            assert_eq!(b.depth(), 0, "drained batcher must be empty");
            assert!(b.next_batch().is_none(), "closed+empty batcher returns None");
        })
        .assert_pass("batcher close-flush vs concurrent submit");
    }

    fn tiny_router(model: &Model, netlist: LutNetlist) -> Router {
        RouterBuilder::new(model.clone())
            .circuit(netlist)
            .engine(Policy::Logic)
            .batch_policy(BatchPolicy { max_batch: 1, max_wait: NEVER, ..Default::default() })
            .workers(1)
            .build()
            .expect("router build inside the model")
    }

    /// Model 2: a classify races a hot-swap install. The registry contract:
    /// whichever side of the swap the submit lands on, the reply arrives
    /// and is bit-exact (a submit rejected by the draining router retries
    /// on the replacement inside `classify`). Synthesis runs *outside* the
    /// model; only the serving-stack interleavings are explored.
    #[test]
    fn registry_hot_swap_vs_racing_classify() {
        let model = random_model("mcswap", 4, &[3], 2, 1, 5);
        let netlist = run_flow(&model, &FlowConfig { jobs: 1, ..Default::default() }, None)
            .expect("synthesis outside the model")
            .circuit
            .netlist;
        let x: Vec<f64> = (0..4).map(|j| (j as f64 * 0.4).sin()).collect();
        let expected = nullanet_tiny::nn::eval::classify(&model, &x);

        let cfg = mc::Config {
            max_preemptions: 1,
            max_iterations: 30_000,
            ..mc::Config::default()
        };
        mc::check(cfg, || {
            let reg = Arc::new(ModelRegistry::new(RegistryConfig {
                batch_policy: BatchPolicy {
                    max_batch: 1,
                    max_wait: NEVER,
                    ..Default::default()
                },
                workers: 1,
            }));
            reg.install("m", tiny_router(&model, netlist.clone()), None).unwrap();

            let rc = Arc::clone(&reg);
            let xc = x.clone();
            let classifier = thread::spawn(move || {
                let rx = rc.classify(Some("m"), &xc).expect("model stays routable");
                let reply = rx.recv().expect("reply must survive the hot-swap drain");
                reply.class
            });

            // Racing hot-swap: drains the old router while the classify is
            // in flight.
            reg.install("m", tiny_router(&model, netlist.clone()), None).unwrap();

            let class = classifier.join().unwrap();
            assert_eq!(class, expected, "reply must be bit-exact across the swap");
            reg.shutdown_all();
        })
        .assert_pass("registry hot-swap vs racing classify");
    }

    /// Model 3: pool shutdown with queued jobs. The lost-wakeup bug class
    /// this guards: a shutdown flag outside the queue mutex lets a worker
    /// check the flag, miss the notify, and park forever — `drop(pool)`
    /// then never joins. Under the model that schedule WILL be explored,
    /// and the deadlock reported with a replay seed.
    #[test]
    fn threadpool_shutdown_loses_no_wakeup_and_no_job() {
        mc::check(mc::Config::default(), || {
            let pool = ThreadPool::new(2);
            let done = Arc::new(AtomicUsize::new(0));
            for _ in 0..2 {
                let d = Arc::clone(&done);
                pool.execute(move || {
                    d.fetch_add(1, Ordering::SeqCst);
                });
            }
            drop(pool); // close + join: must terminate under every schedule
            assert_eq!(done.load(Ordering::SeqCst), 2, "queued jobs must all run");
        })
        .assert_pass("threadpool shutdown");
    }

    /// Model 4: the sharded packed kernel writes disjoint ranges of one
    /// shared output buffer through a raw base pointer. Under every
    /// interleaving of the two shard workers and the helping caller, the
    /// result must equal the single-threaded reference — any aliasing or
    /// missing-barrier bug shows up as a wrong bit.
    #[test]
    fn shard_runner_disjoint_writes_match_reference() {
        let model = random_model("mcshard", 4, &[3], 2, 1, 9);
        let netlist = run_flow(&model, &FlowConfig { jobs: 1, ..Default::default() }, None)
            .expect("synthesis outside the model")
            .circuit
            .netlist;
        let sim = Arc::new(CompiledNetlist::compile(&netlist));

        // 130 samples -> 3 lane groups -> 2 shards on a 2-worker pool.
        let n = 130;
        let ni = sim.num_inputs();
        let mut batch = PackedBatch::with_capacity(ni, n);
        for s in 0..n {
            batch.push_sample(&BitVec::from_bools(
                (0..ni).map(|i| (s * 7 + i * 3) % 5 < 2),
            ));
        }
        let batch = Arc::new(batch);
        let mut scratch = sim.make_scratch();
        let reference = sim.run_packed(&batch, &mut scratch);

        mc::check(mc::Config::default(), || {
            let pool = ThreadPool::new(2);
            let out = CompiledNetlist::run_packed_sharded(&sim, &pool, &batch);
            for s in 0..n {
                for j in 0..sim.num_outputs() {
                    assert_eq!(
                        out.get(s, j),
                        reference.get(s, j),
                        "sharded output differs at sample {s} output {j}"
                    );
                }
            }
        })
        .assert_pass("shard runner disjoint writes");
    }

    /// Model 5 (ISSUE 8): the blocking server's connection-table handshake.
    /// `handle_client` inserts its token into the table FIRST and checks the
    /// stop flag second; `begin_shutdown` sets the flag FIRST and walks the
    /// table second. That pairing guarantees a connection is either
    /// half-closed by the walk or sees the flag before parking in a blocking
    /// read — flipping either ordering admits a schedule where a freshly
    /// accepted connection parks forever, which the checker reports as a
    /// deadlock with a replay seed.
    #[test]
    fn blocking_server_register_then_stop_check_never_strands_a_read() {
        mc::check(mc::Config::default(), || {
            let stop = Arc::new(AtomicBool::new(false));
            // (registered, closed): one table slot standing in for the
            // connection-table entry plus its socket's half-close state.
            let table = Arc::new((
                Mutex::named("server.conns", (false, false)),
                Condvar::new(),
            ));

            let st = Arc::clone(&stop);
            let tb = Arc::clone(&table);
            let handler = thread::spawn(move || {
                let (m, cv) = &*tb;
                {
                    let mut g = m.lock();
                    g.0 = true; // register the token...
                }
                if st.load(Ordering::SeqCst) {
                    return; // ...then check stop before parking in read
                }
                // Park in the blocking read; only shutdown() on the socket
                // (modeled as the closed flag) can wake it now.
                let mut g = m.lock();
                while !g.1 {
                    g = cv.wait(g);
                }
            });

            let st2 = Arc::clone(&stop);
            let tb2 = Arc::clone(&table);
            let admin = thread::spawn(move || {
                st2.store(true, Ordering::SeqCst); // set the flag first...
                let (m, cv) = &*tb2;
                let mut g = m.lock();
                if g.0 {
                    g.1 = true; // ...then walk the table and half-close
                    cv.notify_all();
                }
            });

            // Termination under every schedule IS the invariant.
            handler.join().unwrap();
            admin.join().unwrap();
        })
        .assert_pass("blocking server register/stop handshake");
    }

    /// Model 6 (ISSUE 8): event-loop shutdown vs racing reply writes. The
    /// event loop parks in `wait()`; a batcher dispatcher publishes a reply
    /// and rings the waker; an admin shutdown races both. The loop's pending
    /// queue is the shared state, the eventfd waker a condvar. Invariant: a
    /// published reply is delivered exactly once, or — if it landed after the
    /// final drain — left visibly queued (abandoned with the connection, the
    /// documented shutdown contract). Never lost while the loop still runs,
    /// never double-delivered.
    #[test]
    fn event_loop_shutdown_vs_racing_reply_writes() {
        mc::check(mc::Config::default(), || {
            // (waker signals, pending replies, stop)
            let state = Arc::new((
                Mutex::named("server.evloop", (0usize, Vec::<usize>::new(), false)),
                Condvar::new(),
            ));
            let delivered = Arc::new(AtomicUsize::new(0));

            let s1 = Arc::clone(&state);
            let dispatcher = thread::spawn(move || {
                let (m, cv) = &*s1;
                let mut g = m.lock();
                g.1.push(1);
                g.0 += 1;
                cv.notify_one();
            });
            let s2 = Arc::clone(&state);
            let admin = thread::spawn(move || {
                let (m, cv) = &*s2;
                let mut g = m.lock();
                g.2 = true;
                g.0 += 1;
                cv.notify_one();
            });

            // The loop body: wait -> pump -> stop-check, then a final drain
            // on the way out (mirrors serve_event's structure).
            let (m, cv) = &*state;
            loop {
                let mut g = m.lock();
                while g.0 == 0 {
                    g = cv.wait(g);
                }
                g.0 = 0;
                delivered.fetch_add(g.1.drain(..).count(), Ordering::SeqCst);
                if g.2 {
                    break;
                }
            }
            {
                let mut g = m.lock();
                delivered.fetch_add(g.1.drain(..).count(), Ordering::SeqCst);
            }
            dispatcher.join().unwrap();
            admin.join().unwrap();

            let g = m.lock();
            assert_eq!(
                delivered.load(Ordering::SeqCst) + g.1.len(),
                1,
                "reply must be delivered exactly once or still visibly queued"
            );
        })
        .assert_pass("event-loop shutdown vs racing reply writes");
    }
}
