//! Integration: the multi-model serving registry — concurrent per-model
//! bit-exactness, live hot-swap under load, and the TCP wire protocol's
//! model routing + admin commands (ISSUE 3 acceptance criteria).

use std::sync::Arc;
use std::time::Duration;

use nullanet_tiny::coordinator::{
    BatchPolicy, ModelRegistry, Policy, RegistryConfig, Router, RouterBuilder,
};
use nullanet_tiny::flow::{artifact, run_flow, FlowConfig};
use nullanet_tiny::logic::netlist::LutNetlist;
use nullanet_tiny::nn::model::{random_model, Model};

fn synth(model: &Model) -> LutNetlist {
    run_flow(model, &FlowConfig { jobs: 1, ..Default::default() }, None)
        .unwrap()
        .circuit
        .netlist
}

fn router_for(model: &Model, netlist: LutNetlist) -> Router {
    RouterBuilder::new(model.clone())
        .circuit(netlist)
        .engine(Policy::Logic)
        .batch_policy(BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_micros(500),
            ..Default::default()
        })
        .workers(2)
        .build()
        .unwrap()
}

/// Two models served from one registry, hammered concurrently: every reply
/// must be bit-exact against *its own* model's exact integer NN — a
/// misroute would answer with the other model's (different) predictions.
#[test]
fn concurrent_classify_against_two_models_is_bit_exact_per_model() {
    let ma = random_model("rega", 6, &[5, 4], 3, 1, 41);
    let mb = random_model("regb", 6, &[5, 4], 3, 1, 42);
    let reg = Arc::new(ModelRegistry::new(RegistryConfig {
        batch_policy: BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_micros(500),
            ..Default::default()
        },
        workers: 2,
    }));
    reg.install("rega", router_for(&ma, synth(&ma)), None).unwrap();
    reg.install("regb", router_for(&mb, synth(&mb)), None).unwrap();

    let mut joins = Vec::new();
    for t in 0..4u64 {
        let reg = Arc::clone(&reg);
        let (name, model) =
            if t % 2 == 0 { ("rega", ma.clone()) } else { ("regb", mb.clone()) };
        joins.push(std::thread::spawn(move || {
            for i in 0..60u64 {
                let x: Vec<f64> = (0..6)
                    .map(|j| ((t * 97 + i * 13 + j) as f64 * 0.19).sin())
                    .collect();
                let want = nullanet_tiny::nn::eval::classify(&model, &x);
                let reply = reg
                    .classify(Some(name), &x)
                    .unwrap()
                    .recv_timeout(Duration::from_secs(10))
                    .unwrap();
                assert_eq!(reply.class, want, "model {name} req {i}");
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    // 2 threads × 60 requests per model, all counted on the right metrics.
    for info in reg.infos() {
        assert_eq!(info.depth, 0);
    }
    use std::sync::atomic::Ordering;
    let a = reg.get(Some("rega")).unwrap();
    let b = reg.get(Some("regb")).unwrap();
    assert_eq!(a.metrics().logic_requests.load(Ordering::Relaxed), 120);
    assert_eq!(b.metrics().logic_requests.load(Ordering::Relaxed), 120);
    reg.shutdown_all();
}

/// Hot-swap under sustained load: clients keep classifying while the
/// model's router is repeatedly replaced. Every submit that succeeded must
/// receive its reply (the displaced router drains before release), every
/// reply must be bit-exact (same weights across swaps ⇒ any misroute or
/// torn state would show up as a wrong class), and submits that race the
/// swap window retry transparently inside `classify`.
#[test]
fn hot_swap_under_load_drops_and_misroutes_nothing() {
    let model = random_model("swap", 6, &[5, 4], 3, 1, 43);
    let netlist = synth(&model);
    let reg = Arc::new(ModelRegistry::new(RegistryConfig::default()));
    reg.install("swap", router_for(&model, netlist.clone()), None).unwrap();

    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut joins = Vec::new();
    for t in 0..4u64 {
        let reg = Arc::clone(&reg);
        let m = model.clone();
        joins.push(std::thread::spawn(move || {
            let mut served = 0u64;
            for i in 0..200u64 {
                let x: Vec<f64> = (0..6)
                    .map(|j| ((t * 131 + i * 7 + j) as f64 * 0.23).cos())
                    .collect();
                let want = nullanet_tiny::nn::eval::classify(&m, &x);
                let rx = reg.classify(Some("swap"), &x).expect("model must stay routable");
                let reply = rx
                    .recv_timeout(Duration::from_secs(10))
                    .expect("no reply may be dropped across a hot-swap drain");
                assert_eq!(reply.class, want, "client {t} req {i}");
                served += 1;
            }
            served
        }));
    }
    // Swap the engine out from under the clients, repeatedly.
    let swapper = {
        let reg = Arc::clone(&reg);
        let model = model.clone();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut swaps = 0u64;
            while !stop.load(std::sync::atomic::Ordering::Acquire) {
                reg.install("swap", router_for(&model, netlist.clone()), None).unwrap();
                swaps += 1;
                std::thread::sleep(Duration::from_millis(5));
            }
            swaps
        })
    };
    let mut total = 0;
    for j in joins {
        total += j.join().unwrap();
    }
    stop.store(true, std::sync::atomic::Ordering::Release);
    let swaps = swapper.join().unwrap();
    assert_eq!(total, 800, "every submitted request must be answered");
    assert!(swaps >= 2, "the test must actually have swapped under load ({swaps})");
    reg.shutdown_all();
}

/// The full artifact → registry path over TCP: a directory of compiled
/// bundles is scanned at startup, both models classify bit-exact by name,
/// and a third bundle is loaded live through the admin command.
#[test]
fn models_dir_scan_and_live_load_over_tcp() {
    use std::io::{BufRead, BufReader, Write};

    let dir = "/tmp/nnt_registry_models_dir";
    std::fs::remove_dir_all(dir).ok();
    std::fs::create_dir_all(dir).unwrap();
    let ma = random_model("dira", 5, &[4, 3], 2, 1, 51);
    let mb = random_model("dirb", 5, &[4, 3], 2, 1, 52);
    let fa = run_flow(&ma, &FlowConfig { jobs: 1, ..Default::default() }, None).unwrap();
    let fb = run_flow(&mb, &FlowConfig { jobs: 1, ..Default::default() }, None).unwrap();
    artifact::save_circuit(&format!("{dir}/dira.circuit.json"), &fa.circuit, &ma).unwrap();
    artifact::save_circuit(&format!("{dir}/dirb.circuit.json"), &fb.circuit, &mb).unwrap();
    // A model JSON sharing the directory must be skipped, not fatal.
    ma.save(&format!("{dir}/dira.model.json")).unwrap();

    let reg = Arc::new(ModelRegistry::new(RegistryConfig::default()));
    let loaded = reg.load_dir(dir).unwrap();
    assert_eq!(loaded, vec!["dira".to_string(), "dirb".to_string()]);
    // Sorted scan ⇒ deterministic default.
    assert_eq!(reg.default_name().as_deref(), Some("dira"));

    let (tx, rx) = nullanet_tiny::util::sync::mpsc::channel();
    let r2 = Arc::clone(&reg);
    let server = std::thread::spawn(move || {
        nullanet_tiny::coordinator::server::serve(r2, "127.0.0.1:0", Some(tx)).unwrap();
    });
    let port = rx.recv_timeout(Duration::from_secs(5)).unwrap();
    let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();

    let x = vec![0.4, -0.1, 0.7, -0.8, 0.2];
    for (name, model) in [("dira", &ma), ("dirb", &mb)] {
        conn.write_all(
            format!(
                "{{\"model\": \"{name}\", \"features\": [0.4, -0.1, 0.7, -0.8, 0.2]}}\n"
            )
            .as_bytes(),
        )
        .unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = nullanet_tiny::util::json::Json::parse(&line).unwrap();
        assert_eq!(
            resp.get("class").unwrap().as_usize().unwrap(),
            nullanet_tiny::nn::eval::classify(model, &x),
            "model {name}: {line}"
        );
    }

    // Live-load a third bundle from outside the scanned directory.
    let mc = random_model("dirc", 5, &[4, 3], 2, 1, 53);
    let fc = run_flow(&mc, &FlowConfig { jobs: 1, ..Default::default() }, None).unwrap();
    let extra = "/tmp/nnt_registry_extra.circuit.json";
    artifact::save_circuit(extra, &fc.circuit, &mc).unwrap();
    conn.write_all(format!("{{\"cmd\": \"load\", \"path\": \"{extra}\"}}\n").as_bytes())
        .unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\"") && line.contains("dirc"), "{line}");
    conn.write_all(
        b"{\"model\": \"dirc\", \"features\": [0.4, -0.1, 0.7, -0.8, 0.2]}\n",
    )
    .unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let resp = nullanet_tiny::util::json::Json::parse(&line).unwrap();
    assert_eq!(
        resp.get("class").unwrap().as_usize().unwrap(),
        nullanet_tiny::nn::eval::classify(&mc, &x),
        "{line}"
    );

    conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    server.join().unwrap();
    std::fs::remove_dir_all(dir).ok();
    std::fs::remove_file(extra).ok();
}

/// A bundle-less artifact directory fails loudly, and duplicate model
/// names across bundles are a startup error, not a silent hot-swap.
#[test]
fn load_dir_rejects_duplicates() {
    let dir = "/tmp/nnt_registry_dup_dir";
    std::fs::remove_dir_all(dir).ok();
    std::fs::create_dir_all(dir).unwrap();
    let m = random_model("dup", 5, &[4, 3], 2, 1, 61);
    let f = run_flow(&m, &FlowConfig { jobs: 1, ..Default::default() }, None).unwrap();
    artifact::save_circuit(&format!("{dir}/one.circuit.json"), &f.circuit, &m).unwrap();
    artifact::save_circuit(&format!("{dir}/two.circuit.json"), &f.circuit, &m).unwrap();
    let reg = ModelRegistry::new(RegistryConfig::default());
    let err = reg.load_dir(dir).unwrap_err();
    assert!(err.to_string().contains("two artifacts provide model 'dup'"), "{err}");
    reg.shutdown_all();
    std::fs::remove_dir_all(dir).ok();
}
