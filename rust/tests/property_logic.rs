//! Property-based tests over the logic-synthesis core invariants
//! (in-tree shrinking harness: `util::proptest`).

use nullanet_tiny::logic::cube::Cover;
use nullanet_tiny::logic::espresso::minimize_tt;
use nullanet_tiny::logic::mapper::{map_aig, MapConfig};
use nullanet_tiny::logic::retime::retime_min_period;
use nullanet_tiny::logic::truthtable::TruthTable;
use nullanet_tiny::util::proptest::{check, check_simple, Config, Gen};

/// Random incompletely-specified function: (nvars, on, dc) disjoint.
fn gen_ics(g: &mut Gen) -> (usize, TruthTable, TruthTable) {
    let nvars = g.sized_range(1, 9);
    let on = TruthTable::from_fn(nvars, |_| g.rng.bernoulli(0.4));
    let dc_raw = TruthTable::from_fn(nvars, |_| g.rng.bernoulli(0.25));
    let dc = dc_raw.and(&on.not());
    (nvars, on, dc)
}

#[test]
fn espresso_respects_bounds_and_is_irredundant() {
    check_simple(
        "espresso-bounds",
        gen_ics,
        |(nvars, on, dc)| {
            let (cover, _) = minimize_tt(on, dc);
            let ctt = TruthTable::from_cover(&cover);
            if !on.implies(&ctt) {
                return Err("ON not covered".into());
            }
            if !ctt.implies(&on.or(dc)) {
                return Err("exceeds ON ∪ DC".into());
            }
            // irredundant: dropping any cube must lose ON coverage
            for i in 0..cover.len() {
                let mut cubes = cover.cubes.clone();
                cubes.remove(i);
                let smaller = TruthTable::from_cover(&Cover::from_cubes(*nvars, cubes));
                if on.implies(&smaller) {
                    return Err(format!("cube {i} redundant"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn espresso_never_worse_than_isop() {
    check_simple(
        "espresso-vs-isop",
        gen_ics,
        |(_nvars, on, dc)| {
            let (cover, _) = minimize_tt(on, dc);
            let isop = TruthTable::isop(on, dc);
            if cover.len() > isop.len() {
                return Err(format!(
                    "espresso {} cubes > isop {}",
                    cover.len(),
                    isop.len()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn complement_is_exact_involution() {
    check_simple(
        "complement",
        |g| {
            let nvars = g.sized_range(1, 8);
            TruthTable::from_fn(nvars, |_| g.rng.bernoulli(0.5))
        },
        |tt| {
            let cover = TruthTable::isop(tt, &TruthTable::zeros(tt.nvars()));
            let comp = cover.complement();
            let back = comp.complement();
            if TruthTable::from_cover(&comp) != tt.not() {
                return Err("complement wrong".into());
            }
            if TruthTable::from_cover(&back) != *tt {
                return Err("double complement not identity".into());
            }
            Ok(())
        },
    );
}

#[test]
fn mapping_preserves_function_and_respects_k() {
    // Random AIGs built from a random op tape; shrink by truncating the tape.
    type Tape = Vec<(u8, usize, usize, bool)>;
    fn build(nin: usize, tape: &Tape) -> nullanet_tiny::logic::aig::Aig {
        use nullanet_tiny::logic::aig::{lit_not, Aig, Lit};
        let mut g = Aig::new();
        let mut pool: Vec<Lit> = (0..nin).map(|_| g.add_input()).collect();
        for &(op, a, b, inv) in tape {
            let la = pool[a % pool.len()];
            let lb = pool[b % pool.len()];
            let l = match op % 3 {
                0 => g.and(la, lb),
                1 => g.or(la, lb),
                _ => g.xor(la, lb),
            };
            pool.push(if inv { lit_not(l) } else { l });
        }
        let out = *pool.last().unwrap();
        g.add_output(out);
        g
    }
    check(
        "mapper",
        &Config::default(),
        |g| {
            let n = g.sized_range(1, 40);
            let tape: Tape = (0..n)
                .map(|_| {
                    (
                        g.rng.next_u32() as u8,
                        g.rng.next_u32() as usize,
                        g.rng.next_u32() as usize,
                        g.rng.bernoulli(0.3),
                    )
                })
                .collect();
            tape
        },
        |tape| {
            let mut out = Vec::new();
            if tape.len() > 1 {
                out.push(tape[..tape.len() / 2].to_vec());
                out.push(tape[..tape.len() - 1].to_vec());
            }
            out
        },
        |tape| {
            if tape.is_empty() {
                return Ok(());
            }
            let g = build(7, tape);
            for k in [4usize, 6] {
                let res = map_aig(&g, &MapConfig { k, ..Default::default() });
                if res.netlist.max_arity() > k {
                    return Err(format!("arity {} > k {k}", res.netlist.max_arity()));
                }
                for m in 0..128u64 {
                    if res.netlist.eval(m) != g.eval(m) {
                        return Err(format!("function mismatch at m={m} k={k}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn retiming_never_increases_depth_and_preserves_function() {
    use nullanet_tiny::logic::netlist::{LutNetlist, PipelinedCircuit, Sig};
    check_simple(
        "retime",
        |g| {
            // random DAG of 1–2 input LUTs over a random stage budget
            let nin = g.sized_range(1, 4);
            let nluts = g.sized_range(1, 30);
            let stages = g.sized_range(1, 4) as u32;
            let mut nl = LutNetlist::new(nin);
            for j in 0..nluts {
                let navail = nin + j;
                let k = 1 + g.rng.below(2) as usize;
                let inputs: Vec<Sig> = (0..k)
                    .map(|_| {
                        let pick = g.rng.below(navail as u64) as usize;
                        if pick < nin {
                            Sig::Input(pick as u32)
                        } else {
                            Sig::Lut((pick - nin) as u32)
                        }
                    })
                    .collect();
                let tt = TruthTable::from_fn(k, |_| g.rng.bernoulli(0.5));
                nl.add_lut(inputs, tt);
            }
            nl.add_output(Sig::Lut((nluts - 1) as u32), false);
            PipelinedCircuit {
                stage_of_lut: vec![0; nl.luts.len()],
                netlist: nl,
                num_stages: stages,
            }
        },
        |c| {
            let (r, st) = retime_min_period(c);
            r.check_stages().map_err(|e| e.to_string())?;
            if st.depth_after > st.depth_before {
                return Err(format!(
                    "depth increased {} → {}",
                    st.depth_before, st.depth_after
                ));
            }
            for m in 0..1u64 << c.netlist.num_inputs.min(6) {
                if r.eval(m) != c.eval(m) {
                    return Err(format!("function changed at m={m}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn compiled_sim_agrees_with_interpreter() {
    use nullanet_tiny::logic::netlist::{LutNetlist, Sig};
    use nullanet_tiny::logic::sim::CompiledNetlist;
    check_simple(
        "compiled-sim",
        |g| {
            let nin = g.sized_range(1, 8);
            let nluts = g.sized_range(1, 25);
            let mut nl = LutNetlist::new(nin);
            for j in 0..nluts {
                let navail = nin + j;
                let k = 1 + g.rng.below(5.min(navail as u64)) as usize;
                let inputs: Vec<Sig> = (0..k)
                    .map(|_| {
                        let pick = g.rng.below(navail as u64) as usize;
                        if pick < nin {
                            Sig::Input(pick as u32)
                        } else {
                            Sig::Lut((pick - nin) as u32)
                        }
                    })
                    .collect();
                let tt = TruthTable::from_fn(k, |_| g.rng.bernoulli(0.5));
                nl.add_lut(inputs, tt);
            }
            for j in 0..nluts.min(3) {
                nl.add_output(Sig::Lut(j as u32), j % 2 == 0);
            }
            let words: Vec<u64> = (0..nin).map(|_| g.rng.next_u64()).collect();
            (nl, words)
        },
        |(nl, words)| {
            let want = nl.simulate_words(words);
            let sim = CompiledNetlist::compile(nl);
            let mut scratch = sim.make_scratch();
            let mut got = vec![0u64; want.len()];
            sim.run_words(&mut scratch, words, &mut got);
            if got != want {
                return Err("compiled sim disagrees with interpreter".into());
            }
            Ok(())
        },
    );
}

/// Random netlist generator shared by the packed-path differential
/// properties: arities 0–6, inputs drawn with replacement (duplicate input
/// signals), occasional constant inputs, occasional exact duplicates of an
/// earlier LUT (structural-dedup fodder), and LUTs no output reaches (dead
/// logic). Returns the netlist plus a non-multiple-of-64/-W sample list.
fn gen_packed_case(g: &mut Gen) -> (nullanet_tiny::logic::netlist::LutNetlist, Vec<u64>) {
    use nullanet_tiny::logic::netlist::{LutNetlist, Sig};
    let nin = g.sized_range(1, 10);
    let nluts = g.sized_range(1, 24);
    let mut nl = LutNetlist::new(nin);
    for j in 0..nluts {
        let navail = nin + j;
        // Sometimes clone an earlier LUT verbatim: structural duplicates
        // the compile-time optimizer must merge without changing behavior.
        if j > 0 && g.rng.bernoulli(0.15) {
            let src = g.rng.below(j as u64) as usize;
            let (inputs, table) =
                (nl.luts[src].inputs.clone(), nl.luts[src].table.clone());
            nl.add_lut(inputs, table);
            continue;
        }
        let k = g.rng.below(7) as usize; // arity 0..=6
        let inputs: Vec<Sig> = (0..k)
            .map(|_| {
                // Constant inputs occur too: constant-folding fodder.
                if g.rng.bernoulli(0.1) {
                    return Sig::Const(g.rng.bernoulli(0.5));
                }
                let pick = g.rng.below(navail as u64) as usize;
                if pick < nin {
                    Sig::Input(pick as u32)
                } else {
                    Sig::Lut((pick - nin) as u32)
                }
            })
            .collect();
        let tt = TruthTable::from_fn(k, |_| g.rng.bernoulli(0.5));
        nl.add_lut(inputs, tt);
    }
    // Only the first few LUTs feed outputs, so later ones are often dead.
    for j in 0..nluts.min(4) {
        nl.add_output(Sig::Lut(j as u32), j % 2 == 1);
    }
    nl.add_output(Sig::Input(0), true);
    nl.add_output(Sig::Const(true), false);
    let nsamples = g.sized_range(1, 700);
    let mask = if nin == 64 { !0u64 } else { (1u64 << nin) - 1 };
    let samples: Vec<u64> = (0..nsamples).map(|_| g.rng.next_u64() & mask).collect();
    (nl, samples)
}

#[test]
fn packed_multiworker_matches_reference_eval() {
    // Differential property for the packed serving path: random netlists
    // (duplicate LUTs, constant inputs, dead logic, arities 0–6),
    // non-multiple-of-64 batch sizes, evaluated with 1/2/4 workers sharing
    // one Arc<CompiledNetlist> — every sample's packed output bits must
    // equal the LutNetlist::eval reference.
    use nullanet_tiny::logic::sim::CompiledNetlist;
    use nullanet_tiny::util::bitvec::PackedBatch;
    use nullanet_tiny::util::threadpool::ThreadPool;
    use std::sync::Arc;
    check_simple(
        "packed-multiworker",
        gen_packed_case,
        |(nl, samples)| {
            let nin = nl.num_inputs;
            let mut packed = PackedBatch::with_capacity(nin, samples.len());
            let mut bools = vec![false; nin];
            for &bits in samples {
                for (i, b) in bools.iter_mut().enumerate() {
                    *b = (bits >> i) & 1 == 1;
                }
                packed.push_sample_bools(&bools);
            }
            let sim = Arc::new(CompiledNetlist::compile(nl));
            let batch = Arc::new(packed);
            for workers in [1usize, 2, 4] {
                let pool = ThreadPool::new(workers);
                let out = CompiledNetlist::run_packed_sharded(&sim, &pool, &batch);
                if out.num_samples() != samples.len() {
                    return Err("sample count changed".into());
                }
                for (s, &bits) in samples.iter().enumerate() {
                    let want = nl.eval(bits);
                    for (j, &w) in want.iter().enumerate() {
                        if out.get(s, j) != w {
                            return Err(format!(
                                "mismatch at sample {s} output {j} with {workers} workers"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn optimizer_and_every_block_width_match_reference_eval() {
    // ISSUE 5 differential property: the compile-time optimizer and every
    // wide-lane kernel width must be bit-exact against LutNetlist::eval on
    // netlists with duplicate LUTs, constant inputs, dead logic, and
    // arities 0–6, over batch sizes that are multiples of neither 64 nor
    // the block width, with the sharded runner reused across batches.
    use nullanet_tiny::logic::opt::optimize;
    use nullanet_tiny::logic::sim::{CompiledNetlist, ShardRunner};
    use nullanet_tiny::util::bitvec::PackedBatch;
    use nullanet_tiny::util::threadpool::ThreadPool;
    use std::sync::Arc;
    check_simple(
        "optimizer-block-widths",
        gen_packed_case,
        |(nl, samples)| {
            // The optimizer itself: equivalent, and its stats partition the
            // removed LUTs.
            let (opt_nl, stats) = optimize(nl);
            if stats.luts_after != opt_nl.num_luts() {
                return Err("stats.luts_after disagrees with the netlist".into());
            }
            if stats.removed() != stats.const_folded + stats.deduped + stats.dead_removed
            {
                return Err("optimizer passes must partition the removed LUTs".into());
            }
            for &bits in samples.iter().take(16) {
                if opt_nl.eval(bits) != nl.eval(bits) {
                    return Err(format!("optimized netlist differs at {bits:#x}"));
                }
            }

            let nin = nl.num_inputs;
            let mut packed = PackedBatch::with_capacity(nin, samples.len());
            let mut bools = vec![false; nin];
            for &bits in samples {
                for (i, b) in bools.iter_mut().enumerate() {
                    *b = (bits >> i) & 1 == 1;
                }
                packed.push_sample_bools(&bools);
            }
            let groups = packed.num_groups();

            // Every block width × {optimized, unoptimized} compile.
            for (label, sim) in [
                ("optimized", CompiledNetlist::compile(nl)),
                ("unoptimized", CompiledNetlist::compile_unoptimized(nl)),
            ] {
                let no = sim.num_outputs();
                let mut scratch = sim.make_scratch();
                for cap in [1usize, 2, 4, 8] {
                    let mut out = vec![0u64; groups * no];
                    sim.run_groups_capped(&packed, 0, groups, &mut scratch, &mut out, cap);
                    for (s, &bits) in samples.iter().enumerate() {
                        let want = nl.eval(bits);
                        for (j, &w) in want.iter().enumerate() {
                            let got = (out[(s >> 6) * no + j] >> (s & 63)) & 1 == 1;
                            if got != w {
                                return Err(format!(
                                    "{label} W≤{cap}: mismatch at sample {s} output {j}"
                                ));
                            }
                        }
                    }
                }
            }

            // Sharded runner, reused across two batches (1/2/4 workers).
            let sim = Arc::new(CompiledNetlist::compile(nl));
            let batch = Arc::new(packed);
            let no = sim.num_outputs();
            for workers in [1usize, 2, 4] {
                let pool = ThreadPool::new(workers);
                let mut runner = ShardRunner::new(&sim);
                for round in 0..2 {
                    let words = runner.run(&sim, &pool, &batch);
                    for (s, &bits) in samples.iter().enumerate() {
                        let want = nl.eval(bits);
                        for (j, &w) in want.iter().enumerate() {
                            let got = (words[(s >> 6) * no + j] >> (s & 63)) & 1 == 1;
                            if got != w {
                                return Err(format!(
                                    "sharded ×{workers} round {round}: mismatch at \
                                     sample {s} output {j}"
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
#[cfg_attr(miri, ignore = "spawns rustc subprocesses")]
fn native_codegen_matches_reference_interpreter_and_sat() {
    // ISSUE 9 differential property: every generated netlist is (a) proven
    // equivalent to its optimized form by SAT CEC over the pre-codegen
    // netlist, then (b) lowered to native code via rustc and compared
    // word-exactly against both `LutNetlist::eval` and the interpreter on
    // the same packed batch. Each case costs a full rustc build (~0.5 s),
    // so the case count is far below the harness default and shrinking is
    // disabled (a shrink search would recompile per step). Hosts without a
    // rustc on PATH skip with a notice instead of failing.
    use nullanet_tiny::logic::cec::{check_netlists, CecResult};
    use nullanet_tiny::logic::codegen;
    use nullanet_tiny::logic::opt::optimize;
    use nullanet_tiny::logic::sim::CompiledNetlist;
    use nullanet_tiny::util::bitvec::{mask_group_tail, PackedBatch};
    use std::sync::atomic::{AtomicUsize, Ordering};

    if !codegen::rustc_available() {
        eprintln!("skipping native-codegen property: no usable rustc on this host");
        return;
    }
    static CASE: AtomicUsize = AtomicUsize::new(0);
    let config = Config { cases: 8, ..Config::default() };
    check(
        "native-codegen-differential",
        &config,
        gen_packed_case,
        |_| Vec::new(),
        |(nl, samples)| {
            let case = CASE.fetch_add(1, Ordering::Relaxed);
            let so_path = std::env::temp_dir()
                .join(format!("nnt-prop-native-{}-{case}.so", std::process::id()))
                .to_string_lossy()
                .into_owned();
            let result = (|| -> Result<(), String> {
                // (a) SAT proof that the optimizer preserved the function —
                // the netlist codegen consumes is the optimized one, so this
                // pins the whole pre-codegen pipeline.
                let (opt_nl, _) = optimize(nl);
                match check_netlists(nl, &opt_nl) {
                    Ok(CecResult::Equivalent) => {}
                    Ok(CecResult::Inequivalent { output, .. }) => {
                        return Err(format!("SAT: optimizer broke output {output}"));
                    }
                    Err(e) => return Err(format!("SAT check failed: {e}")),
                }

                // (b) Native build + word-exact three-way comparison.
                let sim = CompiledNetlist::compile(nl);
                let (lib, _) = codegen::load_or_build(&sim, &format!("prop-{case}"), &so_path)
                    .map_err(|e| format!("codegen: {e}"))?;
                let nin = nl.num_inputs;
                let mut packed = PackedBatch::with_capacity(nin, samples.len());
                let mut bools = vec![false; nin];
                for &bits in samples {
                    for (i, b) in bools.iter_mut().enumerate() {
                        *b = (bits >> i) & 1 == 1;
                    }
                    packed.push_sample_bools(&bools);
                }
                let groups = packed.num_groups();
                let no = sim.num_outputs();
                let mut native = vec![0u64; groups * no];
                lib.eval_groups(packed.words(), groups, &mut native);
                mask_group_tail(&mut native, no, samples.len());
                let mut scratch = sim.make_scratch();
                let mut interp = vec![0u64; groups * no];
                sim.run_groups_capped(&packed, 0, groups, &mut scratch, &mut interp, 4);
                mask_group_tail(&mut interp, no, samples.len());
                if native != interp {
                    return Err("native output words differ from the interpreter".into());
                }
                for (s, &bits) in samples.iter().enumerate() {
                    let want = nl.eval(bits);
                    for (j, &w) in want.iter().enumerate() {
                        let got = (native[(s >> 6) * no + j] >> (s & 63)) & 1 == 1;
                        if got != w {
                            return Err(format!(
                                "native: mismatch at sample {s} output {j}"
                            ));
                        }
                    }
                }
                Ok(())
            })();
            for p in [so_path.clone(), format!("{so_path}.rs"), format!("{so_path}.meta")] {
                let _ = std::fs::remove_file(p);
            }
            result
        },
    );
}

#[test]
fn neuron_synthesis_equivalence_property() {
    use nullanet_tiny::flow::synth::{synthesize_neuron, verify_neuron};
    use nullanet_tiny::nn::model::random_model;
    check_simple(
        "neuron-synth",
        |g| {
            let feats = g.sized_range(3, 8);
            let fanin = g.sized_range(2, 4);
            let bits = g.sized_range(1, 2);
            let seed = g.rng.next_u64();
            (feats, fanin, bits, seed)
        },
        |&(feats, fanin, bits, seed)| {
            let m = random_model("p", feats, &[3, 2], fanin, bits, seed);
            for layer in 0..2 {
                for neuron in 0..m.layers[layer].out_width {
                    let s = synthesize_neuron(&m, layer, neuron, None, true);
                    verify_neuron(&s)?;
                }
            }
            Ok(())
        },
    );
}
