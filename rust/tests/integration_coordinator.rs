//! Integration: the serving coordinator — batching under concurrency,
//! engine routing, TCP protocol, metrics accounting.

use std::sync::Arc;
use std::time::Duration;

use nullanet_tiny::coordinator::{BatchPolicy, PjrtSpec, Policy, Router, RouterBuilder};
use nullanet_tiny::error::NnError;
use nullanet_tiny::flow::{run_flow, FlowConfig};
use nullanet_tiny::nn::model::{random_model, Model};

fn build_router(policy: Policy, max_batch: usize) -> (Router, Model) {
    let model = random_model("coord", 6, &[5, 4], 3, 1, 13);
    let r = run_flow(&model, &FlowConfig { jobs: 1, ..Default::default() }, None).unwrap();
    let router = RouterBuilder::new(model.clone())
        .circuit(r.circuit.netlist)
        .engine(policy)
        .batch_policy(BatchPolicy {
            max_batch,
            max_wait: Duration::from_micros(500),
            ..Default::default()
        })
        .workers(2)
        .build()
        .unwrap();
    (router, model)
}

#[test]
fn concurrent_clients_share_batches() {
    let (router, model) = build_router(Policy::Logic, 16);
    let router = Arc::new(router);
    let mut joins = Vec::new();
    for t in 0..4 {
        let r = Arc::clone(&router);
        let m = model.clone();
        joins.push(std::thread::spawn(move || {
            for i in 0..50 {
                let x: Vec<f64> =
                    (0..6).map(|j| ((t * 100 + i * 3 + j) as f64 * 0.17).sin()).collect();
                let want = nullanet_tiny::nn::eval::classify(&m, &x);
                let rx = r.submit(x);
                let reply = rx.recv_timeout(Duration::from_secs(10)).unwrap();
                assert_eq!(reply.class, want);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let m = router.metrics();
    use std::sync::atomic::Ordering;
    assert_eq!(m.logic_requests.load(Ordering::Relaxed), 200);
    let batches = m.batches.load(Ordering::Relaxed);
    assert!(batches < 200, "batching must coalesce ({batches} batches for 200 reqs)");
    assert!(m.request_latency.count() == 200);
}

#[test]
fn compare_policy_counts_disagreements() {
    // Without PJRT attached, compare-mode serves logic and records zero
    // disagreements (the numeric side is absent).
    let (router, model) = build_router(Policy::Compare, 8);
    for i in 0..20 {
        let x: Vec<f64> = (0..6).map(|j| ((i + j) as f64 * 0.31).cos()).collect();
        let want = nullanet_tiny::nn::eval::classify(&model, &x);
        let reply = router
            .submit(x)
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        assert_eq!(reply.class, want);
    }
    use std::sync::atomic::Ordering;
    assert_eq!(router.metrics().disagreements.load(Ordering::Relaxed), 0);
    router.shutdown();
}

#[test]
fn pjrt_routing_with_real_artifacts() {
    if !std::path::Path::new("artifacts/jsc-s.hlo.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let model = Model::load("artifacts/jsc-s.model.json").unwrap();
    let flow =
        run_flow(&model, &FlowConfig { jobs: 1, ..Default::default() }, None).unwrap();
    let out_w = model.layers.last().unwrap().out_width;
    let spec = PjrtSpec {
        hlo_path: "artifacts/jsc-s.hlo.txt".into(),
        batch: 64,
        in_features: model.input_features,
        out_width: out_w,
    };
    // Compare mode with the real numeric engine: logic and PJRT should
    // agree on almost every request.
    let router = match RouterBuilder::new(model.clone())
        .circuit(flow.circuit.netlist)
        .pjrt(spec)
        .engine(Policy::Compare)
        .batch_policy(BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_micros(300),
            ..Default::default()
        })
        .workers(2)
        .build()
    {
        Ok(r) => r,
        Err(NnError::Engine(_)) => {
            // Stub build (no `xla` feature): the mirror's PJRT shadow cannot
            // be constructed; that is a typed error, not a hang.
            eprintln!("skipping: PJRT backend not compiled in");
            return;
        }
        Err(e) => panic!("unexpected build error: {e}"),
    };
    let test = nullanet_tiny::data::Dataset::load("artifacts/jsc_test.bin").unwrap();
    let n = 256;
    let rxs: Vec<_> = test.xs[..n].iter().map(|x| router.submit(x.clone())).collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    use std::sync::atomic::Ordering;
    let m = router.metrics();
    assert_eq!(m.logic_requests.load(Ordering::Relaxed) as usize, n);
    assert_eq!(m.numeric_requests.load(Ordering::Relaxed) as usize, n);
    let dis = m.disagreements.load(Ordering::Relaxed) as f64 / n as f64;
    assert!(dis < 0.01, "logic vs pjrt disagreement rate {dis}");
    router.shutdown();
}

#[test]
fn tcp_server_multiple_clients() {
    use std::io::{BufRead, BufReader, Write};
    let (router, model) = build_router(Policy::Logic, 8);
    let registry =
        Arc::new(nullanet_tiny::coordinator::ModelRegistry::with_default("coord", router));
    let (tx, rx) = nullanet_tiny::util::sync::mpsc::channel();
    let r2 = Arc::clone(&registry);
    let server = std::thread::spawn(move || {
        nullanet_tiny::coordinator::server::serve(r2, "127.0.0.1:0", Some(tx)).unwrap();
    });
    let port = rx.recv_timeout(Duration::from_secs(5)).unwrap();

    let mut clients = Vec::new();
    for c in 0..3 {
        let m = model.clone();
        clients.push(std::thread::spawn(move || {
            let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            for i in 0..10 {
                let x: Vec<f64> =
                    (0..6).map(|j| ((c * 31 + i * 7 + j) as f64 * 0.13).sin()).collect();
                let req = format!(
                    "{{\"features\": [{}]}}\n",
                    x.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(",")
                );
                conn.write_all(req.as_bytes()).unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let resp = nullanet_tiny::util::json::Json::parse(&line).unwrap();
                let class = resp.get("class").unwrap().as_usize().unwrap();
                assert_eq!(class, nullanet_tiny::nn::eval::classify(&m, &x));
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    // shutdown
    let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
    conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
    server.join().unwrap();
}
