//! Chaos suite (ISSUE 10): fault-injection tests for the resilience story.
//!
//! Compiled only under `--cfg nnt_fault` — in a tier-1 build this file is an
//! empty test binary, so the suite can never slow or destabilize the default
//! `cargo test`. Under the cfg, every test drives the seeded harness in
//! [`util::fault`] against a real store / router / registry / server and
//! asserts the degraded path, not just the absence of a crash:
//!
//! * the artifact store never serves a torn payload, no matter where the
//!   writer dies;
//! * a `Policy::Native` router always comes up (rustc or dlopen failure
//!   downgrades to the interpreter, counted and correct);
//! * a mid-serve eval fault downgrades the native tier permanently, visibly
//!   (`native>interp` on every subsequent reply) and bit-exactly;
//! * hot-swapping under injected construction/eval faults drops nothing;
//! * the event loop's FIFO reply order survives pathological short writes.
//!
//! Fault decisions are process-global and seeded (`NNT_CHAOS_SEED`, default
//! 1 — CI sweeps three fixed seeds), so the tests serialize on one gate and
//! reset the harness on entry and exit.
#![cfg(nnt_fault)]

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Duration;

use nullanet_tiny::coordinator::{BatchPolicy, ModelRegistry, Policy, Router, RouterBuilder};
use nullanet_tiny::flow::{run_flow, store, FlowConfig};
use nullanet_tiny::logic::codegen;
use nullanet_tiny::nn::model::{random_model, Model};
use nullanet_tiny::util::fault::{self, Plan};
use nullanet_tiny::util::sync::{Mutex, MutexGuard};

/// Seed for the deterministic fault schedule. CI runs the suite once per
/// seed in a small fixed set; a local repro is `NNT_CHAOS_SEED=n cargo test
/// --test chaos` with `RUSTFLAGS="--cfg nnt_fault"`.
fn chaos_seed() -> u64 {
    std::env::var("NNT_CHAOS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(1)
}

/// The harness state (plans, seeds, counters) is process-global; tests that
/// arm points must not interleave. `cargo test` runs test fns concurrently
/// in one process, so every test holds this gate for its whole body.
fn gate() -> MutexGuard<'static, ()> {
    static GATE: OnceLock<Mutex<()>> = OnceLock::new();
    GATE.get_or_init(|| Mutex::named("chaos.gate", ())).lock()
}

fn tmp_dir(tag: &str) -> String {
    static N: AtomicU64 = AtomicU64::new(0);
    let n = N.fetch_add(1, Ordering::Relaxed);
    let dir =
        std::env::temp_dir().join(format!("nnt-chaos-{}-{tag}-{n}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.to_string_lossy().into_owned()
}

/// The tiny model every serving test builds routers from.
fn chaos_model(seed: u64) -> Model {
    random_model("chaos", 6, &[5, 4], 3, 1, seed)
}

fn build_router(model: &Model, policy: Policy, cache: Option<&str>) -> Router {
    let r = run_flow(model, &FlowConfig { jobs: 1, ..Default::default() }, None).unwrap();
    let mut b = RouterBuilder::new(model.clone())
        .circuit(r.circuit.netlist)
        .engine(policy)
        .batch_policy(BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_micros(500),
            ..Default::default()
        })
        .workers(2);
    if let Some(c) = cache {
        b = b.native_cache(c);
    }
    b.build().unwrap()
}

fn sample(i: usize) -> Vec<f64> {
    (0..6).map(|j| ((i * 7 + j) as f64 * 0.23).sin()).collect()
}

/// Kill-during-write never corrupts: across many publishes where the seeded
/// harness aborts the writer at either fault site (payload temp write or
/// journal write), `load` always returns the **last successfully published**
/// payload — never a torn one, never an error — and the generation number
/// advances exactly once per success.
#[test]
fn store_never_serves_a_torn_payload_under_write_faults() {
    let _g = gate();
    fault::reset();
    let dir = tmp_dir("store");
    let path = format!("{dir}/model.json");

    // Generation 1 lands fault-free so there is always a last-good payload.
    let mut last = b"chaos payload 0".to_vec();
    assert_eq!(store::publish(&path, &last).unwrap(), 1);

    fault::set_seed(chaos_seed());
    fault::arm("artifact.write", Plan::Permille(400));
    let (mut successes, mut failures) = (0u64, 0u64);
    for i in 1..=40 {
        let payload = format!("chaos payload {i}").into_bytes();
        match store::publish(&path, &payload) {
            Ok(_) => {
                successes += 1;
                last = payload;
            }
            Err(_) => failures += 1,
        }
        // The invariant, checked after every attempt: whatever the writer
        // just did (or died doing), a reader sees the last good payload.
        let loaded = store::load(&path).unwrap();
        assert_eq!(loaded.bytes, last, "load diverged after attempt {i}");
    }
    assert!(failures > 0, "seed {} injected no write faults", chaos_seed());
    assert!(successes > 0, "seed {} failed every publish", chaos_seed());
    assert!(fault::injected("artifact.write") > 0);
    assert_eq!(store::generation(&path), Some(1 + successes));
    fault::reset();
    let _ = std::fs::remove_dir_all(&dir);
}

/// rustc failing at build time is a construction fault the router absorbs:
/// `Policy::Native` still comes up, serving bit-identical answers on the
/// interpreter tier, with the downgrade counted.
#[test]
fn injected_rustc_failure_downgrades_native_to_interpreter() {
    let _g = gate();
    fault::reset();
    fault::set_seed(chaos_seed());
    fault::arm("codegen.rustc", Plan::Always);
    let dir = tmp_dir("rustc");
    let model = chaos_model(6);
    let cache = format!("{dir}/native.so");
    let router = build_router(&model, Policy::Native, Some(cache.as_str()));
    assert_eq!(router.engine_name(), "logic");
    assert!(router.metrics().fallback_downgrades.load(Ordering::Relaxed) >= 1);
    for i in 0..8 {
        let x = sample(i);
        let want = nullanet_tiny::nn::eval::classify(&model, &x);
        let reply = router.submit(x).recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(reply.class, want);
    }
    // Without rustc the ladder falls back before ever reaching the build
    // step, so the injection counter only moves where rustc exists.
    if codegen::rustc_available() {
        assert!(fault::injected("codegen.rustc") >= 1);
    }
    router.shutdown();
    fault::reset();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Same ladder, next rung: the build succeeds but `dlopen` refuses the
/// library. Still a construction fault, still absorbed.
#[test]
fn injected_dlopen_failure_downgrades_native_to_interpreter() {
    let _g = gate();
    fault::reset();
    fault::set_seed(chaos_seed());
    fault::arm("dlopen", Plan::Always);
    let dir = tmp_dir("dlopen");
    let model = chaos_model(7);
    let cache = format!("{dir}/native.so");
    let router = build_router(&model, Policy::Native, Some(cache.as_str()));
    assert_eq!(router.engine_name(), "logic");
    assert!(router.metrics().fallback_downgrades.load(Ordering::Relaxed) >= 1);
    for i in 0..8 {
        let x = sample(i);
        let want = nullanet_tiny::nn::eval::classify(&model, &x);
        let reply = router.submit(x).recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(reply.class, want);
    }
    if codegen::rustc_available() {
        assert!(fault::injected("dlopen") >= 1);
    }
    router.shutdown();
    fault::reset();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The mid-serve story (satellite f): a healthy native engine that takes an
/// eval fault downgrades **permanently**, the tier change is visible on
/// every subsequent reply (`native>interp`), and the interpreter re-serves
/// the faulted batch bit-exactly — the client never sees the fault.
#[test]
fn eval_fault_downgrades_mid_serve_permanently_and_bit_exactly() {
    let _g = gate();
    if !codegen::rustc_available() {
        eprintln!("skipping: mid-serve downgrade needs a real native engine (no rustc)");
        return;
    }
    fault::reset();
    fault::set_seed(chaos_seed());
    let dir = tmp_dir("eval");
    let model = chaos_model(8);
    let cache = format!("{dir}/native.so");
    let router = build_router(&model, Policy::Native, Some(cache.as_str()));
    assert_eq!(router.engine_name(), "native");

    // Healthy tier first.
    let x = sample(0);
    let want = nullanet_tiny::nn::eval::classify(&model, &x);
    let reply = router.submit(x).recv_timeout(Duration::from_secs(10)).unwrap();
    assert_eq!((reply.class, reply.engine), (want, "native"));

    // One injected eval fault: the batch that absorbs it is still answered
    // correctly (re-served on the interpreter) and labelled with the tier
    // that actually produced it.
    fault::arm("engine.eval", Plan::Times(1));
    for i in 1..16 {
        let x = sample(i);
        let want = nullanet_tiny::nn::eval::classify(&model, &x);
        let reply = router.submit(x).recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(reply.class, want, "request {i} after the fault");
    }
    assert_eq!(fault::injected("engine.eval"), 1);
    assert_eq!(router.metrics().fallback_downgrades.load(Ordering::Relaxed), 1);

    // Permanent: long after the fault plan is spent, the tier stays down.
    let x = sample(99);
    let want = nullanet_tiny::nn::eval::classify(&model, &x);
    let reply = router.submit(x).recv_timeout(Duration::from_secs(10)).unwrap();
    assert_eq!((reply.class, reply.engine), (want, "native>interp"));
    router.shutdown();
    fault::reset();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Hot-swapping under injected construction and eval faults drops nothing:
/// clients hammer the registry while the same model is reinstalled behind
/// their backs with `Policy::Native` routers whose construction randomly
/// fails at rustc or dlopen (falling back to the interpreter) and whose
/// native tier randomly downgrades mid-serve. Every reply arrives and every
/// reply is correct.
#[test]
fn hot_swap_under_injected_faults_drops_nothing() {
    let _g = gate();
    fault::reset();
    fault::set_seed(chaos_seed());
    let dir = tmp_dir("swap");
    let model = chaos_model(9);
    let cache = format!("{dir}/native.so");

    let first = build_router(&model, Policy::Logic, None);
    let registry = Arc::new(ModelRegistry::with_default("chaos", first));

    fault::arm("codegen.rustc", Plan::Permille(400));
    fault::arm("dlopen", Plan::Permille(400));
    fault::arm("engine.eval", Plan::Permille(200));

    let mut clients = Vec::new();
    for t in 0..2 {
        let reg = Arc::clone(&registry);
        let m = model.clone();
        clients.push(std::thread::spawn(move || {
            for i in 0..60 {
                let x = sample(t * 1000 + i);
                let want = nullanet_tiny::nn::eval::classify(&m, &x);
                // Admission control may push back while a displaced router
                // drains; overload is a typed, retryable verdict — what must
                // never happen is an admitted request going unanswered.
                let rx = loop {
                    match reg.classify(None, &x) {
                        Ok(rx) => break rx,
                        Err(_) => std::thread::sleep(Duration::from_millis(1)),
                    }
                };
                let reply = rx.recv_timeout(Duration::from_secs(10)).unwrap();
                assert_eq!(reply.class, want, "client {t} request {i}");
            }
        }));
    }
    for _ in 0..5 {
        std::thread::sleep(Duration::from_millis(10));
        let next = build_router(&model, Policy::Native, Some(cache.as_str()));
        registry.install("chaos", next, None).unwrap();
    }
    for c in clients {
        c.join().unwrap();
    }
    registry.unload("chaos").unwrap();
    fault::reset();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite (c), server side: the event loop's strict-FIFO reply order and
/// backpressure machinery survive pathological short writes. With
/// `socket.write` armed, every injected flush moves a single byte, so
/// replies dribble out across many loop iterations while the backlog (and
/// the pause/resume water marks guarding it) stays engaged — yet the client
/// still receives every reply, complete and in request order.
#[cfg(target_os = "linux")]
#[test]
fn event_loop_fifo_order_survives_injected_short_writes() {
    use nullanet_tiny::coordinator::{frame, server};
    use nullanet_tiny::util::sync::mpsc;
    use std::io::Write;

    let _g = gate();
    fault::reset();
    fault::set_seed(chaos_seed());
    let model = chaos_model(10);
    let router = build_router(&model, Policy::Logic, None);
    let registry = Arc::new(ModelRegistry::with_default("chaos", router));

    let (tx, rx) = mpsc::channel();
    let reg = Arc::clone(&registry);
    let srv = std::thread::spawn(move || {
        server::serve_event(reg, "127.0.0.1:0", Some(tx)).unwrap();
    });
    let port = rx.recv_timeout(Duration::from_secs(5)).unwrap();

    fault::arm("socket.write", Plan::Permille(500));
    let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
    let n = 16;
    let mut expected = Vec::with_capacity(n);
    let mut wire = Vec::new();
    let r = registry.get(None).unwrap();
    for i in 0..n {
        let x = sample(i);
        expected.push(nullanet_tiny::nn::eval::classify(&model, &x) as u16);
        let bits = r.binarize(&x);
        wire.extend(frame::encode_classify_req(None, bits.len() as u16, bits.words()));
    }
    // One pipelined burst: all requests on the wire before any reply read.
    conn.write_all(&wire).unwrap();
    let mut buf = Vec::new();
    for (i, want) in expected.iter().enumerate() {
        match read_frame(&mut conn, &mut buf) {
            frame::Frame::ClassifyResp { classes } => {
                assert_eq!(classes, vec![*want], "reply {i} out of FIFO order");
            }
            other => panic!("reply {i}: expected a classify resp, got {other:?}"),
        }
    }
    assert!(
        fault::injected("socket.write") > 0,
        "seed {} never shortened a write",
        chaos_seed()
    );

    fault::reset();
    let mut ctl = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
    ctl.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
    srv.join().unwrap();
}

/// Read one complete frame off a blocking client socket, tolerating the
/// byte-at-a-time arrival the short-write fault produces.
#[cfg(target_os = "linux")]
fn read_frame(
    stream: &mut std::net::TcpStream,
    buf: &mut Vec<u8>,
) -> nullanet_tiny::coordinator::frame::Frame {
    use nullanet_tiny::coordinator::frame;
    use std::io::Read;
    let mut chunk = [0u8; 4096];
    loop {
        if let Some((f, n)) = frame::decode(buf).unwrap() {
            buf.drain(..n);
            return f;
        }
        let n = stream.read(&mut chunk).unwrap();
        assert!(n > 0, "server closed mid-frame");
        buf.extend_from_slice(&chunk[..n]);
    }
}
