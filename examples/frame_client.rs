//! Binary-protocol client for a running `nullanet serve` (ISSUE 8).
//!
//! Quantizes deterministic feature vectors client-side with the model's own
//! input quantizer, packs them into length-prefixed classify frames, and
//! drives the server through a pipelined window — the CI smoke uses it to
//! exercise the sniffed binary path and the typed overload rejection
//! end to end.
//!
//! ```bash
//! cargo run --release --example frame_client -- \
//!     --addr 127.0.0.1:7878 --model-file /tmp/tiny.model.json \
//!     --count 64 --window 8 [--model NAME] [--expect-overload]
//! ```
//!
//! Exit status: `0` when every request got a classify response (or, with
//! `--expect-overload`, when at least one typed overload frame came back);
//! nonzero on protocol errors, transport errors, or unmet expectations.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::process::ExitCode;
use std::time::{Duration, Instant};

use nullanet_tiny::coordinator::frame::{self, Frame};
use nullanet_tiny::nn::eval::{codes_to_bitvec, quantize_input};
use nullanet_tiny::nn::model::Model;
use nullanet_tiny::util::cli::Args;
use nullanet_tiny::util::prng::Xoshiro256;

/// Read one complete frame, accumulating partial reads in `buf`.
fn read_frame(stream: &mut TcpStream, buf: &mut Vec<u8>) -> Result<Frame, String> {
    loop {
        match frame::decode(buf).map_err(|e| format!("protocol error: {e}"))? {
            Some((f, n)) => {
                buf.drain(..n);
                return Ok(f);
            }
            None => {
                let mut chunk = [0u8; 4096];
                let n = stream.read(&mut chunk).map_err(|e| format!("read: {e}"))?;
                if n == 0 {
                    return Err("server closed the connection mid-reply".into());
                }
                buf.extend_from_slice(&chunk[..n]);
            }
        }
    }
}

fn run() -> Result<(), String> {
    let args = Args::parse(std::env::args().skip(1))?;
    args.check_known(&[
        "addr",
        "model-file",
        "model",
        "count",
        "window",
        "expect-overload",
    ])?;
    let addr = args.get_str("addr", "127.0.0.1:7878");
    let model_file = args.get_str("model-file", "");
    if model_file.is_empty() {
        return Err("--model-file <model.json> is required (client-side quantizer)".into());
    }
    let named = args.get_str("model", "");
    let named = (!named.is_empty()).then_some(named);
    let count = args.get_usize("count", 64)?;
    let window = args.get_usize("window", 8)?.max(1);
    let expect_overload = args.get_bool("expect-overload");

    let model = Model::load(&model_file).map_err(|e| format!("{model_file}: {e}"))?;

    // Deterministic inputs → deterministic frames (same seed the serve
    // bench uses, so smoke failures replay exactly).
    let mut rng = Xoshiro256::new(0xC0FFEE);
    let frames: Vec<Vec<u8>> = (0..count)
        .map(|_| {
            let x: Vec<f64> = (0..model.input_features)
                .map(|_| 2.0 * rng.next_gaussian())
                .collect();
            let codes = quantize_input(&model, &x);
            let bits = codes_to_bitvec(&codes, model.input_quant.bits);
            frame::encode_classify_req(named.as_deref(), bits.len() as u16, bits.words())
        })
        .collect();

    let mut stream = TcpStream::connect(&addr).map_err(|e| format!("{addr}: {e}"))?;
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .map_err(|e| e.to_string())?;

    let (mut ok, mut overload, mut error) = (0usize, 0usize, 0usize);
    let mut buf = Vec::new();
    let mut sent = 0usize;
    let t0 = Instant::now();
    while ok + overload + error < count {
        // Keep `window` requests in flight: the server answers strictly in
        // order, so replies pair with requests positionally.
        while sent < count && sent < ok + overload + error + window {
            stream
                .write_all(&frames[sent])
                .map_err(|e| format!("write: {e}"))?;
            sent += 1;
        }
        match read_frame(&mut stream, &mut buf)? {
            Frame::ClassifyResp { classes } => {
                if classes.len() != 1 {
                    return Err(format!("expected 1 class per reply, got {}", classes.len()));
                }
                ok += 1;
            }
            Frame::Overload { message } => {
                if overload == 0 {
                    println!("overload: {message}");
                }
                overload += 1;
            }
            Frame::Error { message } => {
                eprintln!("server error: {message}");
                error += 1;
            }
            f => return Err(format!("unexpected frame from server: {f:?}")),
        }
    }
    let wall = t0.elapsed();
    println!(
        "{count} requests over binary frames (window {window}): {ok} ok, \
         {overload} overloaded, {error} errors, {:.0} req/s",
        count as f64 / wall.as_secs_f64().max(1e-9),
    );

    if error > 0 {
        return Err(format!("{error} typed error replies"));
    }
    if expect_overload {
        if overload == 0 {
            return Err("expected at least one overload rejection, saw none".into());
        }
    } else if overload > 0 {
        return Err(format!("{overload} unexpected overload rejections"));
    }
    Ok(())
}

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("frame_client: {e}");
            ExitCode::FAILURE
        }
    }
}
