//! Quickstart: the NullaNet Tiny flow on a tiny model, start to finish.
//!
//! Builds a small random quantized fanin-constrained network (no training
//! needed — the flow is training-agnostic), converts every neuron into
//! optimized combinational logic, verifies the circuit is bit-exact against
//! the network, and prints the hardware cost a VU9P-class FPGA would pay.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use nullanet_tiny::flow::{run_flow, FlowConfig};
use nullanet_tiny::fpga::timing::TimingModel;
use nullanet_tiny::logic::verilog::pipelined_to_verilog;
use nullanet_tiny::nn::model::random_model;

fn main() {
    // 1. A model: 8 features → 10 → 6 → 3 classes, 2-bit activations,
    //    fanin ≤ 3 (6-bit neuron functions — one native 6-LUT each).
    let model = random_model("quickstart", 8, &[10, 6, 3], 3, 2, 42);
    println!("model: {}\n", model.summary());

    // 2. The flow: enumerate → ESPRESSO-II → AIG → 6-LUT map → retime.
    let result = run_flow(&model, &FlowConfig::default(), None).expect("flow");
    println!("{}", result.timer.report("flow stages"));

    // 3. Hardware cost.
    let stats = result.circuit.stats();
    let tm = TimingModel::vu9p();
    println!(
        "hardware: {} LUTs, {} FFs, {} pipeline stages, worst stage depth {}",
        stats.luts, stats.ffs, stats.latency_cycles, stats.max_stage_depth
    );
    println!(
        "timing:   fmax {:.0} MHz, end-to-end latency {:.2} ns",
        tm.fmax_mhz(stats.max_stage_depth),
        tm.latency_ns(stats.latency_cycles, stats.max_stage_depth)
    );
    println!(
        "espresso: {} cubes → {} cubes across {} neurons\n",
        result.total_cubes_before, result.total_cubes_after, result.neurons
    );

    // 4. Bit-exactness (the flow already verified; show it explicitly).
    nullanet_tiny::flow::build::verify_circuit(&model, &result.circuit, 1000, 7)
        .expect("circuit ≡ quantized NN");
    println!("verified: circuit ≡ quantized network on 1000 random samples");

    // 5. RTL out (first lines).
    let verilog = pipelined_to_verilog(&result.circuit, "quickstart");
    let head: String = verilog.lines().take(6).collect::<Vec<_>>().join("\n");
    println!("\nverilog preview:\n{head}\n…");
}
