//! End-to-end driver (DESIGN.md F1): the full pipeline on the real
//! workload — trained JSC model → combinational logic → Table-I row —
//! proving all three layers compose:
//!
//!   L2/L1 (Python, already run by `make artifacts`): QAT + FCP training
//!   with the Pallas masked-dense kernel, exported to model.json + HLO.
//!   L3 (this binary): logic synthesis, verification, FPGA cost,
//!   test-set accuracy via the bit-parallel simulator, and cross-check
//!   against the PJRT numeric engine.
//!
//! ```bash
//! make artifacts && cargo run --release --example jsc_flow -- --arch jsc-s
//! ```

use nullanet_tiny::baseline::{build_logicnets, AqpModel};
use nullanet_tiny::data::Dataset;
use nullanet_tiny::flow::{circuit_accuracy, run_flow, FlowConfig};
use nullanet_tiny::fpga::area::Device;
use nullanet_tiny::fpga::report::{format_table, Comparison, ResultRow};
use nullanet_tiny::fpga::timing::TimingModel;
use nullanet_tiny::nn::model::Model;
use nullanet_tiny::runtime::PjrtEngine;
use nullanet_tiny::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1)).expect("args");
    let arch = args.get_str("arch", "jsc-s");
    let dir = args.get_str("artifacts", "artifacts");

    // ---- load the trained artifacts (built by `make artifacts`) ----
    let model = Model::load(&format!("{dir}/{arch}.model.json"))
        .expect("model artifact (run `make artifacts` first)");
    let base_model = Model::load(&format!("{dir}/{arch}.logicnets.model.json"))
        .expect("baseline model artifact");
    let test = Dataset::load(&format!("{dir}/jsc_test.bin")).expect("test set");
    println!("model: {}", model.summary());
    println!("test set: {} samples\n", test.len());

    // ---- the flow ----
    let result = run_flow(&model, &FlowConfig::default(), None).expect("flow");
    println!("{}", result.timer.report(&format!("{arch} flow stages (Fig. 1)")));

    // ---- accuracy: exact NN vs logic circuit (must agree exactly) ----
    let nn_acc = nullanet_tiny::nn::eval::accuracy(&model, &test.xs, &test.ys);
    let logic_acc = circuit_accuracy(&model, &result.circuit, &test.xs, &test.ys);
    println!("accuracy: quantized NN {:.2}%  |  logic circuit {:.2}%", nn_acc * 100.0, logic_acc * 100.0);
    assert!((nn_acc - logic_acc).abs() < 1e-12, "logic must be bit-exact");

    // ---- PJRT numeric cross-check ----
    let hlo = format!("{dir}/{arch}.hlo.txt");
    if std::path::Path::new(&hlo).exists() {
        let out_w = model.layers.last().unwrap().out_width;
        let engine = PjrtEngine::load(&hlo, 64, model.input_features, out_w).expect("pjrt");
        let n = 2048.min(test.len());
        let pjrt_pred = engine.classify_all(&test.xs[..n], model.num_classes).unwrap();
        let rust_pred: Vec<usize> = test.xs[..n]
            .iter()
            .map(|x| nullanet_tiny::nn::eval::classify(&model, x))
            .collect();
        let agree = pjrt_pred.iter().zip(&rust_pred).filter(|(a, b)| a == b).count();
        println!(
            "PJRT ({}) agreement with integer eval: {}/{} ({:.2}%)",
            engine.platform(),
            agree,
            n,
            100.0 * agree as f64 / n as f64
        );
    }

    // ---- hardware report + baseline comparison (one Table-I row) ----
    let tm = TimingModel::vu9p();
    let base = build_logicnets(&base_model, 6).expect("baseline flow");
    let base_acc = circuit_accuracy(&base_model, &base.circuit, &test.xs, &test.ys);
    let cmp = Comparison {
        ours: ResultRow::from_stats(&arch.to_uppercase(), logic_acc, result.circuit.stats(), &tm),
        baseline: ResultRow::from_stats(
            &arch.to_uppercase(),
            base_acc,
            base.circuit.stats(),
            &tm,
        ),
    };
    println!("\n{}", format_table(std::slice::from_ref(&cmp)));

    let dev = Device::vu9p();
    let (lu, fu) = dev.utilization(&result.circuit.stats());
    println!(
        "VU9P utilization: {:.3}% LUTs, {:.3}% FFs  (device {})",
        lu * 100.0,
        fu * 100.0,
        dev.name
    );
    let aqp = AqpModel::default();
    println!(
        "vs Google AQP-style arithmetic datapath: {:.1} ns vs our {:.2} ns ({:.2}x lower)",
        aqp.latency_ns(&model),
        cmp.ours.latency_ns,
        aqp.latency_ns(&model) / cmp.ours.latency_ns
    );
}
