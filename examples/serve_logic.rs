//! Serving demo: the coordinator routes batched inference requests to the
//! combinational-logic engine (and, when artifacts exist, cross-checks a
//! PJRT numeric engine), reporting latency/throughput percentiles.
//!
//! ```bash
//! cargo run --release --example serve_logic -- --requests 20000 [--arch jsc-s]
//! ```

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use nullanet_tiny::coordinator::{BatchPolicy, PjrtSpec, Policy, RouterBuilder};
use nullanet_tiny::flow::{run_flow, FlowConfig};
use nullanet_tiny::nn::model::{random_model, Model};
use nullanet_tiny::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1)).expect("args");
    let n_requests = args.get_usize("requests", 20_000).expect("--requests");
    let arch = args.get_str("arch", "jsc-s");
    let dir = args.get_str("artifacts", "artifacts");

    // Use the trained model when available, else a stand-in.
    let model_path = format!("{dir}/{arch}.model.json");
    let (model, pjrt) = if std::path::Path::new(&model_path).exists() {
        let m = Model::load(&model_path).expect("model");
        let out_w = m.layers.last().unwrap().out_width;
        let hlo = format!("{dir}/{arch}.hlo.txt");
        let spec = std::path::Path::new(&hlo).exists().then(|| PjrtSpec {
            hlo_path: hlo,
            batch: 64,
            in_features: m.input_features,
            out_width: out_w,
        });
        // Only mirror onto PJRT when the backend can actually be built
        // (stub builds preflight-fail); otherwise serve logic alone.
        let spec = spec.filter(|s| s.preflight().is_ok());
        (m, spec)
    } else {
        println!("(artifacts missing; serving a random model, logic only)");
        (random_model("serve", 16, &[32, 16, 5], 3, 2, 7), None)
    };
    println!("model: {}", model.summary());

    println!("synthesizing logic…");
    let flow = run_flow(&model, &FlowConfig::default(), None).expect("flow");
    let policy = if pjrt.is_some() { Policy::Compare } else { Policy::Logic };
    // Shard multi-lane-group batches across the default worker count
    // sharing one compiled netlist.
    let workers = RouterBuilder::default_workers();
    let mut builder = RouterBuilder::new(model.clone())
        .circuit(flow.circuit.netlist.clone())
        .engine(policy)
        .batch_policy(BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_micros(100),
            ..Default::default()
        })
        .workers(workers);
    if let Some(spec) = pjrt {
        builder = builder.pjrt(spec);
    }
    let router = Arc::new(builder.build().expect("router"));

    // Drive the server from 4 closed-loop clients.
    println!("serving {n_requests} requests (policy {policy:?})…");
    let t0 = Instant::now();
    let per_client = n_requests / 4;
    let mut joins = Vec::new();
    for c in 0..4u64 {
        let r = Arc::clone(&router);
        let feats = model.input_features;
        joins.push(std::thread::spawn(move || {
            use nullanet_tiny::util::prng::Xoshiro256;
            let mut rng = Xoshiro256::new(0x5EED ^ c);
            for _ in 0..per_client {
                let x: Vec<f64> = (0..feats).map(|_| 2.0 * rng.next_gaussian()).collect();
                let rx = r.submit(x);
                let _ = rx.recv_timeout(Duration::from_secs(30)).expect("reply");
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let wall = t0.elapsed();

    let m = router.metrics();
    let served = 4 * per_client;
    println!("\n── serving report ──");
    println!("{}", m.report());
    println!(
        "throughput: {:.0} inferences/s (wall {:.2}s, {} batches, avg batch {:.1})",
        served as f64 / wall.as_secs_f64(),
        wall.as_secs_f64(),
        m.batches.load(Ordering::Relaxed),
        served as f64 / m.batches.load(Ordering::Relaxed).max(1) as f64,
    );
    if policy == Policy::Compare {
        let dis = m.disagreements.load(Ordering::Relaxed);
        println!("logic vs PJRT disagreements: {dis}/{served}");
    }
}
