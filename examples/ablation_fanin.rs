//! Ablation A1: fanin-constraint sweep — the accuracy ↔ hardware-cost
//! trade-off that motivates FCP (paper §FCP).
//!
//! For each fanin γ, a fresh random model of JSC-S shape is synthesized and
//! the LUT/FF/depth/fmax cost is reported alongside the enumeration cost
//! 2^(γ·β). (Accuracy as a function of γ is a training-side property —
//! `python -m compile.train --ablate-act` covers A2; this example isolates
//! the hardware side, which needs no training.)
//!
//! ```bash
//! cargo run --release --example ablation_fanin -- [--quick]
//! ```

use nullanet_tiny::flow::{run_flow, FlowConfig};
use nullanet_tiny::fpga::timing::TimingModel;
use nullanet_tiny::nn::model::random_model;
use nullanet_tiny::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1)).expect("args");
    let quick = args.get_bool("quick");
    let bits = 2usize;
    let fanins: Vec<usize> = if quick { vec![2, 3, 4] } else { vec![2, 3, 4, 5, 6] };

    println!("A1: fanin sweep on JSC-S shape (16→64→32→5, β={bits})\n");
    println!(
        "| γ | fn bits | enum 2^n | LUTs | FFs | depth | fmax MHz | flow ms |"
    );
    println!("|---|---------|----------|------|-----|-------|----------|---------|");
    let tm = TimingModel::vu9p();
    for fanin in fanins {
        let model = random_model("sweep", 16, &[64, 32, 5], fanin, bits, 99);
        let t = std::time::Instant::now();
        let cfg = FlowConfig { verify: false, ..Default::default() };
        let r = run_flow(&model, &cfg, None).expect("flow");
        let ms = t.elapsed().as_secs_f64() * 1e3;
        let s = r.circuit.stats();
        println!(
            "| {fanin} | {:7} | {:8} | {:4} | {:3} | {:5} | {:8.0} | {:7.0} |",
            fanin * bits,
            1u64 << (fanin * bits),
            s.luts,
            s.ffs,
            s.max_stage_depth,
            tm.fmax_mhz(s.max_stage_depth),
            ms,
        );
    }
    println!(
        "\nThe exponential enumeration column is why FCP exists: γ·β must stay\n\
         small enough to enumerate, and LUT cost tracks the same exponential\n\
         once γ·β exceeds the native LUT size (6)."
    );
}
