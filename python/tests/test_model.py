"""L2 model tests: kernel/ref path equality, export contract, code-level
replay (the bit-exactness bridge to the Rust flow)."""

import jax.numpy as jnp
import numpy as np

from compile import data, model, quant


def _tiny_trained(arch="jsc-s", seed=0):
    spec = model.make_spec(arch)
    state = model.init_params(spec, seed)
    params, masks = state["params"], state["masks"]
    # prune to fanin immediately (no training needed for these tests)
    from compile import prune
    for li, l in enumerate(spec.layers):
        masks[li] = prune.topk_row_mask(np.asarray(params["w"][li]), l.fanin).astype(
            np.float32
        )
    return spec, params, masks


def test_kernel_and_ref_paths_agree():
    spec, params, masks = _tiny_trained()
    x = np.random.RandomState(1).randn(32, 16).astype(np.float32)
    a = np.asarray(model.forward(params, masks, jnp.asarray(x), spec, use_kernel=False))
    b = np.asarray(model.forward(params, masks, jnp.asarray(x), spec, use_kernel=True))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_predict_shape_and_range():
    spec, params, masks = _tiny_trained()
    x = np.random.RandomState(2).randn(20, 16).astype(np.float32)
    p = np.asarray(model.predict(params, masks, jnp.asarray(x), spec))
    assert p.shape == (20,)
    assert ((p >= 0) & (p < 5)).all()


def test_export_schema():
    spec, params, masks = _tiny_trained()
    mean = np.zeros(16)
    std = np.ones(16)
    e = model.export_model(spec, params, masks, mean, std)
    assert e["name"] == "jsc-s"
    assert e["input_features"] == 16
    assert len(e["layers"]) == 3
    for li, l in enumerate(e["layers"]):
        assert len(l["mask"]) == l["out"]
        for n, (m, w) in enumerate(zip(l["mask"], l["weights"])):
            assert len(m) == len(w) <= spec.layers[li].fanin
            assert m == sorted(m)
        q = l["act"]
        assert len(q["levels"]) == 1 << q["bits"]
        assert len(q["thresholds"]) == len(q["levels"]) - 1


def _code_level_forward(e: dict, x: np.ndarray) -> np.ndarray:
    """NumPy replay of the Rust nn::eval code-level semantics."""
    mean = np.array(e["feature_mean"])
    std = np.array(e["feature_std"])
    iq = e["input_quant"]
    z = (x - mean) / std
    codes = quant.quantize_codes_np(z, np.array(iq["thresholds"]))
    values = np.array(iq["levels"])[codes]
    for l in e["layers"]:
        q = l["act"]
        out_vals = np.zeros((x.shape[0], l["out"]))
        for n in range(l["out"]):
            acc = l["bias"][n] + sum(
                w * values[:, src] for w, src in zip(l["weights"][n], l["mask"][n])
            )
            c = quant.quantize_codes_np(acc, np.array(q["thresholds"]))
            out_vals[:, n] = np.array(q["levels"])[c]
        values = out_vals
    return values


def test_exported_tables_replay_jax_forward():
    """The levels/thresholds replay (what Rust does) must classify samples
    identically to the JAX fake-quant forward, modulo f32-vs-f64 threshold
    ties (required < 2% of samples, none expected in practice)."""
    spec, params, masks = _tiny_trained()
    x, _ = data.generate(400, seed=3)
    mean, std = data.standardize_stats(x)
    e = model.export_model(spec, params, masks, mean, std)

    xn = ((x - mean) / std).astype(np.float32)
    jax_out = np.asarray(model.forward(params, masks, jnp.asarray(xn), spec))
    jax_pred = jax_out[:, :5].argmax(axis=1)

    replay_vals = _code_level_forward(e, x.astype(np.float64))
    replay_pred = replay_vals[:, :5].argmax(axis=1)

    agree = (jax_pred == replay_pred).mean()
    assert agree > 0.98, f"code-level replay agreement {agree}"


def test_uniform_act_spec():
    s = model.make_spec("jsc-m", uniform_act=True)
    assert all(l.act_kind == "signed_uniform" for l in s.layers)
    s2 = model.make_spec("jsc-m", uniform_act=False)
    assert s2.layers[0].act_kind == "pact"
    assert s2.layers[-1].act_kind == "signed_uniform"  # output always signed


def test_arch_table():
    assert set(model.ARCHS) == {"jsc-s", "jsc-m", "jsc-l"}
    for name, cfg in model.ARCHS.items():
        assert cfg["widths"][-1] == 5
        assert cfg["act_bits"] * cfg["fanin"] <= 12, "enumeration feasibility"
