"""Synthetic JSC dataset + binary format tests."""

import os
import tempfile

import numpy as np

from compile import data


def test_generate_deterministic():
    x1, y1 = data.generate(200, seed=9)
    x2, y2 = data.generate(200, seed=9)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    x3, _ = data.generate(200, seed=10)
    assert not np.array_equal(x1, x3)


def test_shapes_and_classes():
    x, y = data.generate(1000, seed=1)
    assert x.shape == (1000, 16)
    assert x.dtype == np.float32
    assert y.dtype == np.uint8
    assert set(np.unique(y)) == {0, 1, 2, 3, 4}


def test_task_difficulty_band():
    """Nearest-class-mean accuracy must land in the 'hard but learnable'
    band (same check as the Rust twin generator)."""
    x, y = data.generate(4000, seed=7)
    mean, std = data.standardize_stats(x[:3000])
    z = (x - mean) / std
    cm = np.stack([z[:3000][y[:3000] == c].mean(axis=0) for c in range(5)])
    d = ((z[3000:, None, :] - cm[None, :, :]) ** 2).sum(axis=2)
    acc = (d.argmin(axis=1) == y[3000:]).mean()
    assert 0.45 < acc < 0.97, f"nearest-mean acc {acc}"


def test_binary_roundtrip():
    x, y = data.generate(50, seed=2)
    with tempfile.TemporaryDirectory() as td:
        p = os.path.join(td, "d.bin")
        data.save(p, x, y)
        x2, y2, c = data.load(p)
        np.testing.assert_array_equal(x, x2)
        np.testing.assert_array_equal(y, y2)
        assert c == 5
        # Exact layout contract with rust/src/data/dataset.rs.
        raw = open(p, "rb").read()
        assert raw[:4] == b"NNTD"
        assert len(raw) == 20 + 50 * 16 * 4 + 50


def test_standardize_stats_floor():
    x = np.zeros((10, 16), dtype=np.float32)
    mean, std = data.standardize_stats(x)
    assert (std >= 1e-9).all()
