"""Quantizer semantics + the levels/thresholds export contract with Rust."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import quant


def test_sign_forward_values():
    x = jnp.array([-2.0, -0.1, 0.0, 0.1, 2.0])
    y = np.asarray(quant.sign_forward(x))
    np.testing.assert_array_equal(y, [-1.0, -1.0, 1.0, 1.0, 1.0])


def test_sign_ste_gradient_clips():
    g = jax.grad(lambda x: quant.sign_forward(x).sum())(jnp.array([-2.0, 0.5, 2.0]))
    np.testing.assert_array_equal(np.asarray(g), [0.0, 1.0, 0.0])


def test_pact_forward_range_and_grads():
    alpha = jnp.array(2.0)
    x = jnp.array([-1.0, 0.5, 1.9, 5.0])
    y = np.asarray(quant.pact_forward(x, alpha, bits=2))
    assert y.min() >= 0.0 and y.max() <= 2.0
    # d/dalpha: 1 per element clipped above (the dominant PACT term) plus
    # the exact quantization-step term for interior elements
    # (round(xc/step) − xc/step)/n — compute the analytical value.
    galpha = jax.grad(lambda a: quant.pact_forward(x, a, 2).sum())(alpha)
    n = 3
    step = 2.0 / n
    interior = [0.5, 1.9]
    expected = 1.0 + sum((round(v / step) - v / step) / n for v in interior)
    assert abs(float(galpha) - expected) < 1e-5
    # STE: gradient w.r.t. x is 1 inside [0, alpha], 0 outside
    gx = jax.grad(lambda x_: quant.pact_forward(x_, alpha, 2).sum())(x)
    np.testing.assert_array_equal(np.asarray(gx), [0.0, 1.0, 1.0, 0.0])


def test_signed_uniform_values():
    y = np.asarray(quant.signed_uniform_forward(jnp.array([-10.0, -0.2, 0.2, 10.0]),
                                                bits=2, scale=0.5))
    # levels: -1.0, -0.5, 0.0, 0.5
    np.testing.assert_array_equal(y, [-1.0, -0.0, 0.0, 0.5])


@settings(max_examples=40, deadline=None)
@given(
    bits=st.integers(1, 4),
    kind=st.sampled_from(["pact", "signed_uniform"]),
    seed=st.integers(0, 10_000),
)
def test_forward_agrees_with_exported_tables(bits, kind, seed):
    """The STE forward and the exported levels/thresholds must agree: for
    any x, forward(x) == levels[searchsorted(thresholds, x)] — this IS the
    Rust contract."""
    rng = np.random.RandomState(seed)
    x = rng.randn(200).astype(np.float32) * 3.0
    if kind == "pact":
        alpha = float(rng.rand() * 3.0 + 0.5)
        y = np.asarray(quant.pact_forward(jnp.asarray(x), jnp.array(alpha), bits))
        exp = quant.export_quantizer("pact", bits, alpha=alpha)
    else:
        scale = float(rng.rand() * 0.9 + 0.1)
        y = np.asarray(quant.signed_uniform_forward(jnp.asarray(x), bits, scale))
        exp = quant.export_quantizer("signed_uniform", bits, scale=scale)
    levels = np.array(exp["levels"], dtype=np.float64)
    thr = np.array(exp["thresholds"], dtype=np.float64)
    codes = quant.quantize_codes_np(x.astype(np.float64), thr)
    want = levels[codes]
    np.testing.assert_allclose(y.astype(np.float64), want, atol=1e-5)


def test_export_shapes():
    e = quant.export_quantizer("pact", 3, alpha=1.5)
    assert len(e["levels"]) == 8
    assert len(e["thresholds"]) == 7
    assert e["bits"] == 3
    assert e["levels"] == sorted(e["levels"])
    s = quant.export_quantizer("sign", 1)
    assert s["levels"] == [-1.0, 1.0]
    assert s["thresholds"] == [0.0]


def test_codes_are_monotone():
    thr = np.array([-0.5, 0.0, 0.5])
    codes = quant.quantize_codes_np(np.array([-1.0, -0.5, -0.1, 0.0, 0.4, 0.5, 1.0]), thr)
    np.testing.assert_array_equal(codes, [0, 1, 1, 2, 2, 3, 3])
