"""L1 kernel correctness: Pallas masked_dense vs the pure-jnp oracle.

Hypothesis sweeps shapes and value distributions; assert_allclose with zero
tolerance — both paths are f32 matmuls on CPU and must agree bitwise.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.masked_dense import (
    masked_dense,
    mxu_utilization_estimate,
    vmem_bytes_estimate,
)
from compile.kernels.ref import masked_dense_ref


def _run_both(x, w, m, b):
    got = masked_dense(jnp.asarray(x), jnp.asarray(w), jnp.asarray(m), jnp.asarray(b))
    want = masked_dense_ref(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(m), jnp.asarray(b)
    )
    return np.asarray(got), np.asarray(want)


@settings(max_examples=25, deadline=None)
@given(
    batch=st.integers(1, 200),
    in_dim=st.integers(1, 48),
    out_dim=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
    density=st.floats(0.05, 1.0),
)
def test_kernel_matches_ref_across_shapes(batch, in_dim, out_dim, seed, density):
    rng = np.random.RandomState(seed)
    x = rng.randn(batch, in_dim).astype(np.float32)
    w = rng.randn(out_dim, in_dim).astype(np.float32)
    m = (rng.rand(out_dim, in_dim) < density).astype(np.float32)
    b = rng.randn(out_dim).astype(np.float32)
    got, want = _run_both(x, w, m, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_kernel_exceeds_one_tile(seed):
    """Shapes beyond one 128×128 tile exercise the grid index maps."""
    rng = np.random.RandomState(seed)
    x = rng.randn(300, 16).astype(np.float32)
    w = rng.randn(192, 16).astype(np.float32)
    m = (rng.rand(192, 16) < 0.25).astype(np.float32)
    b = rng.randn(192).astype(np.float32)
    got, want = _run_both(x, w, m, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_mask_zeroes_contributions():
    """A fully-zero mask must give exactly the bias."""
    rng = np.random.RandomState(0)
    x = rng.randn(8, 10).astype(np.float32)
    w = rng.randn(4, 10).astype(np.float32)
    m = np.zeros((4, 10), dtype=np.float32)
    b = rng.randn(4).astype(np.float32)
    got, _ = _run_both(x, w, m, b)
    np.testing.assert_allclose(got, np.broadcast_to(b, (8, 4)), rtol=0, atol=0)  # bias-only path is exact


def test_extreme_values():
    """Large magnitudes must not diverge between kernel and ref."""
    x = np.array([[1e20, -1e20, 1.0]], dtype=np.float32)
    w = np.array([[1e-20, 1e-20, 1e20]], dtype=np.float32)
    m = np.ones((1, 3), dtype=np.float32)
    b = np.array([0.5], dtype=np.float32)
    got, want = _run_both(x, w, m, b)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_jsc_shapes_bitexact():
    """The exact JSC layer shapes used by the export path."""
    rng = np.random.RandomState(7)
    for (batch, i, o) in [(64, 16, 64), (64, 64, 32), (64, 32, 5), (64, 192, 192)]:
        x = rng.randn(batch, i).astype(np.float32)
        w = rng.randn(o, i).astype(np.float32)
        m = (rng.rand(o, i) < 4 / i).astype(np.float32)
        b = rng.randn(o).astype(np.float32)
        got, want = _run_both(x, w, m, b)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_vmem_estimate_fits_budget():
    """Per-instance VMEM must stay far below a TPU core's ~16 MiB."""
    for (batch, i, o) in [(4096, 192, 192), (128, 16, 64)]:
        assert vmem_bytes_estimate(batch, i, o) < 1 << 22  # 4 MiB


def test_mxu_estimate_range():
    u = mxu_utilization_estimate(128, 128, 128)
    assert u == pytest.approx(1.0)
    assert 0 < mxu_utilization_estimate(64, 16, 5) <= 1.0
