"""FCP: gradual schedule and ADMM invariants."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import prune


@settings(max_examples=30, deadline=None)
@given(
    out=st.integers(1, 30),
    inp=st.integers(1, 40),
    k=st.integers(1, 40),
    seed=st.integers(0, 10_000),
)
def test_topk_mask_row_budget(out, inp, k, seed):
    rng = np.random.RandomState(seed)
    w = rng.randn(out, inp)
    m = prune.topk_row_mask(w, k)
    assert m.shape == w.shape
    assert (m.sum(axis=1) == min(k, inp)).all()
    # kept entries dominate dropped entries in magnitude per row
    for r in range(out):
        if m[r].any() and (~m[r]).any():
            assert np.abs(w[r][m[r]]).min() >= np.abs(w[r][~m[r]]).max() - 1e-12


def test_gradual_schedule_monotone():
    full, target = 64, 4
    ks = [prune.gradual_schedule(s, 100, 900, full, target) for s in range(0, 1200, 10)]
    assert ks[0] == full
    assert ks[-1] == target
    assert all(a >= b for a, b in zip(ks, ks[1:])), "schedule must tighten"


def test_gradual_schedule_boundaries():
    assert prune.gradual_schedule(0, 10, 20, 8, 2) == 8
    assert prune.gradual_schedule(10, 10, 20, 8, 2) == 8  # t=0 keeps full
    assert prune.gradual_schedule(20, 10, 20, 8, 2) == 2
    assert prune.gradual_schedule(99, 10, 20, 8, 2) == 2


def test_admm_converges_to_sparse():
    rng = np.random.RandomState(3)
    w = rng.randn(6, 16)
    pr = prune.AdmmPruner(w.shape, fanin=3, rho=0.1)
    # Simulate training: W drifts toward Z under the penalty.
    for _ in range(200):
        g = pr.penalty_grad(w)
        w = w - 0.5 * g
        pr.update(w)
    m = pr.final_mask(w)
    assert (m.sum(axis=1) <= 3).all()
    # Penalty must have pulled the pruned entries toward zero.
    assert np.abs(w[~m]).mean() < np.abs(w[m]).mean()


def test_admm_projection_idempotent():
    rng = np.random.RandomState(5)
    w = rng.randn(4, 10)
    pr = prune.AdmmPruner(w.shape, fanin=2)
    p1 = pr.project(w)
    p2 = pr.project(p1)
    np.testing.assert_array_equal(p1, p2)
    assert ((p1 != 0).sum(axis=1) <= 2).all()
