"""Training + AOT export smoke tests (short budgets; the full run happens in
`make artifacts`)."""

import os
import tempfile

import numpy as np

from compile import model, train
from compile.aot import export_hlo, to_hlo_text


def test_short_training_learns():
    spec, params, masks, (mean, std), stats = train.train(
        "jsc-s", steps=300, batch=128, quiet=True, train_samples=4000,
        test_samples=2000)
    assert stats["final_test_acc"] > 0.40, "must beat 20% chance decisively"
    # fanin constraint enforced
    for li, l in enumerate(spec.layers):
        assert (masks[li].sum(axis=1) <= l.fanin).all()
    # loss decreased
    assert stats["loss_curve"][-1] < stats["loss_curve"][0]


def test_admm_training_prunes():
    spec, params, masks, _, stats = train.train(
        "jsc-s", steps=300, batch=128, quiet=True, fcp="admm",
        train_samples=3000, test_samples=1000)
    for li, l in enumerate(spec.layers):
        assert (masks[li].sum(axis=1) <= l.fanin).all()
    assert stats["final_test_acc"] > 0.35


def test_hlo_export_is_loadable_text():
    spec, params, masks, (mean, std), _ = train.train(
        "jsc-s", steps=50, batch=64, quiet=True, train_samples=1000,
        test_samples=500)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "m.hlo.txt")
        export_hlo(spec, params, masks, mean, std, path)
        text = open(path).read()
        # HLO text, not proto: must carry the module header and an ENTRY.
        assert text.lstrip().startswith("HloModule")
        assert "ENTRY" in text
        # the exported batch is baked in
        assert "f32[64,16]" in text
        assert len(text) > 1000


def test_hlo_text_roundtrips_through_xla_parser():
    """xla_extension must accept the text we emit (same parser family the
    Rust crate uses)."""
    import jax
    import jax.numpy as jnp
    lowered = jax.jit(lambda a, b: (a @ b + 1.0,)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
    )
    text = to_hlo_text(lowered)
    assert text.lstrip().startswith("HloModule")
    assert "ENTRY" in text
