"""Pure-jnp oracle for the Pallas masked-dense kernel (L1 correctness).

``masked_dense_ref`` is the mathematical definition the kernel must match
bit-for-bit on CPU (both run in f32):

    y = x @ (W ⊙ M)^T + b

where M is the fanin mask from FCP. The activation quantizer is applied
*outside* the kernel (see model.py) so the kernel stays a pure MAC block —
the operation NullaNet Tiny removes from the FPGA and the MXU executes
during training/export.
"""

from __future__ import annotations

import jax.numpy as jnp


def masked_dense_ref(
    x: jnp.ndarray, w: jnp.ndarray, mask: jnp.ndarray, b: jnp.ndarray
) -> jnp.ndarray:
    """Reference masked dense layer.

    Args:
      x: [batch, in] activations.
      w: [out, in] float weights.
      mask: [out, in] {0,1} fanin mask.
      b: [out] bias.

    Returns:
      [batch, out] pre-activations.
    """
    wm = w * mask
    return x @ wm.T + b[None, :]
