"""Pallas masked-dense kernel — the L1 compute hot-spot.

The paper's training module spends its FLOPs in the fanin-masked dense
layers (the very MACs the logic flow later eliminates from the FPGA). This
kernel computes

    y[bt, o] = Σ_i  x[bt, i] · (W[o, i] · M[o, i]) + b[o]

tiled for a TPU: the grid walks (batch, out) tiles; each program instance
keeps an (BM × IN) activation tile, an (BN × IN) masked-weight tile, and a
(BM × BN) output tile resident in VMEM and drives the MXU with a single
`jnp.dot` per tile (f32 accumulation). The mask product folds into the
weight tile load, so HBM traffic per tile is one read of x, one read of W⊙M
and one write of y — the hardware-adaptation story in DESIGN.md §7.

CPU note: `interpret=True` is mandatory here — real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute. Interpret mode lowers
to plain HLO, which is exactly what the AOT artifact wants.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes: MXU-shaped (the systolic array is 128×128; 8-row granularity
# for the VPU). Shapes smaller than a tile fall back to a single block.
BM = 128  # batch tile
BN = 128  # output-neuron tile


def _kernel(x_ref, wm_ref, b_ref, o_ref):
    """One (BM × BN) output tile: masked weights are pre-multiplied; the
    MXU sees a plain f32 matmul."""
    x = x_ref[...]          # [bm, in]
    wm = wm_ref[...]        # [bn, in]
    b = b_ref[...]          # [bn]
    acc = jnp.dot(x, wm.T, preferred_element_type=jnp.float32)
    o_ref[...] = acc + b[None, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_dense(
    x: jnp.ndarray,
    w: jnp.ndarray,
    mask: jnp.ndarray,
    b: jnp.ndarray,
    interpret: bool = True,
) -> jnp.ndarray:
    """Masked dense layer via a Pallas kernel.

    Args:
      x: [batch, in] f32 activations.
      w: [out, in] f32 weights.
      mask: [out, in] f32 {0,1} fanin mask.
      b: [out] f32 bias.
      interpret: keep True on CPU (see module docstring).

    Returns:
      [batch, out] f32 pre-activations.
    """
    batch, in_dim = x.shape
    out_dim, in_dim2 = w.shape
    assert in_dim == in_dim2 and mask.shape == w.shape and b.shape == (out_dim,)

    # The mask product is fused ahead of the kernel so the tile load already
    # carries W ⊙ M (one HBM read, not two).
    wm = (w * mask).astype(jnp.float32)

    bm = min(BM, batch)
    bn = min(BN, out_dim)
    grid = (pl.cdiv(batch, bm), pl.cdiv(out_dim, bn))

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, in_dim), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, in_dim), lambda i, j: (j, 0)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((batch, out_dim), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), wm, b.astype(jnp.float32))


def vmem_bytes_estimate(batch: int, in_dim: int, out_dim: int) -> int:
    """Per-instance VMEM footprint of the kernel (for DESIGN.md §Perf):
    x tile + weight tile + bias + output tile, f32."""
    bm = min(BM, batch)
    bn = min(BN, out_dim)
    return 4 * (bm * in_dim + bn * in_dim + bn + bm * bn)


def mxu_utilization_estimate(batch: int, in_dim: int, out_dim: int) -> float:
    """Fraction of MXU lanes busy for one tile: matmul dims padded to the
    128×128 systolic array."""
    bm = min(BM, batch)
    bn = min(BN, out_dim)

    def pad(v: int) -> int:
        return ((v + 127) // 128) * 128

    useful = bm * in_dim * bn
    padded = pad(bm) * pad(in_dim) * pad(bn)
    return useful / padded
