"""Fanin-constrained pruning (FCP) — L2 training module.

FCP forces every neuron to read at most ``fanin`` inputs so its function can
be enumerated as a 2^(fanin·bits)-row truth table (NullaNet [32]). Two
schemes from the paper:

* **Gradual magnitude pruning** (Zhu & Gupta [11]): per-neuron top-k masks
  tightened on a cubic schedule from full fanin down to the target.
* **ADMM** (Boyd et al. [35], as applied by Zhang et al. [12]): the weights
  are split W = Z with Z constrained to per-row k-sparsity; the augmented
  Lagrangian alternates gradient steps on W, projection for Z, and dual
  updates U += W − Z. At the end W is hard-projected onto the mask of Z.

Both produce the same artifact: a boolean mask of shape [out, in] with at
most ``fanin`` true entries per row.
"""

from __future__ import annotations

import numpy as np


def topk_row_mask(w: np.ndarray, k: int) -> np.ndarray:
    """Boolean mask keeping the k largest-|w| entries of each row."""
    out, inp = w.shape
    k = min(k, inp)
    mask = np.zeros_like(w, dtype=bool)
    idx = np.argsort(-np.abs(w), axis=1)[:, :k]
    rows = np.repeat(np.arange(out), k)
    mask[rows, idx.ravel()] = True
    return mask


def gradual_schedule(step: int, begin: int, end: int, full: int, target: int) -> int:
    """Cubic sparsity ramp of Zhu & Gupta: current per-row k at ``step``.

    Before ``begin``: full fanin; after ``end``: target; in between the kept
    count follows full - (full-target)·(1-(1-t)³).
    """
    if step < begin:
        return full
    if step >= end:
        return target
    t = (step - begin) / max(1, end - begin)
    kept = full - (full - target) * (1.0 - (1.0 - t) ** 3)
    return max(target, int(round(kept)))


class GradualPruner:
    """Stateful gradual FCP: call ``mask_for(step, weights)`` each time the
    mask should be refreshed."""

    def __init__(self, full: int, target: int, begin: int, end: int):
        self.full = full
        self.target = target
        self.begin = begin
        self.end = end

    def mask_for(self, step: int, w: np.ndarray) -> np.ndarray:
        k = gradual_schedule(step, self.begin, self.end, self.full, self.target)
        return topk_row_mask(w, k)


class AdmmPruner:
    """ADMM-based FCP for one weight matrix."""

    def __init__(self, shape: tuple[int, int], fanin: int, rho: float = 1e-2):
        self.fanin = fanin
        self.rho = rho
        self.z = np.zeros(shape, dtype=np.float64)
        self.u = np.zeros(shape, dtype=np.float64)

    def project(self, w: np.ndarray) -> np.ndarray:
        """Euclidean projection of w onto per-row k-sparse matrices."""
        m = topk_row_mask(w, self.fanin)
        return np.where(m, w, 0.0)

    def update(self, w: np.ndarray) -> None:
        """One ADMM round: Z-projection then dual ascent."""
        self.z = self.project(w + self.u)
        self.u = self.u + w - self.z

    def penalty_grad(self, w: np.ndarray) -> np.ndarray:
        """Gradient of (rho/2)·||W − Z + U||² w.r.t. W."""
        return self.rho * (w - self.z + self.u)

    def final_mask(self, w: np.ndarray) -> np.ndarray:
        """Hard mask from the converged Z (ties broken by |w|)."""
        return topk_row_mask(np.where(np.abs(self.z) > 0, w, 0.0), self.fanin)
