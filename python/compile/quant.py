"""Activation quantizers with straight-through estimators (L2, QAT).

NullaNet Tiny's key QAT idea is *per-layer activation selection*: layers
whose inputs span negative values use a signed (sign/bipolar or symmetric
uniform) quantizer, non-negative layers use PACT [9] with a learned clipping
threshold alpha. Weights are NOT quantized — they dissolve into truth tables
during logic synthesis — so QAT here means activation quantization plus
fanin-constrained pruning (prune.py).

Every quantizer exports ``levels`` (code -> reconstruction value) and
``thresholds`` (decision boundaries) arrays; the Rust flow replays those
tables verbatim, which is what makes the logic bit-exact against training.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _round_ste(x: jnp.ndarray) -> jnp.ndarray:
    """Round with a straight-through gradient."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Static description of a quantizer (exported to model.json)."""

    kind: str  # "sign" | "signed_uniform" | "pact"
    bits: int

    @property
    def num_levels(self) -> int:
        return 1 << self.bits


def sign_forward(x: jnp.ndarray) -> jnp.ndarray:
    """Bipolar sign quantizer {-1, +1} with STE (clipped identity grad,
    Hubara et al.): forward emits sign(x), backward passes gradients only
    inside [-1, 1]."""
    s = jnp.where(x >= 0, 1.0, -1.0)
    xc = jnp.clip(x, -1.0, 1.0)
    return xc + jax.lax.stop_gradient(s - xc)


def sign_levels() -> tuple[np.ndarray, np.ndarray]:
    return np.array([-1.0, 1.0]), np.array([0.0])


def signed_uniform_forward(x: jnp.ndarray, bits: int, scale: float) -> jnp.ndarray:
    """Symmetric signed uniform quantizer.

    Codes c in [0, 2^bits) map to values (c - 2^(bits-1)) * scale; the
    forward clamps to the representable range and rounds with STE.
    """
    n = 1 << bits
    half = n // 2
    lo = -half * scale
    hi = (n - 1 - half) * scale
    xc = jnp.clip(x, lo, hi)
    q = _round_ste(xc / scale) * scale
    return q


def signed_uniform_levels(bits: int, scale: float) -> tuple[np.ndarray, np.ndarray]:
    n = 1 << bits
    half = n // 2
    levels = (np.arange(n) - half) * scale
    thresholds = (levels[:-1] + levels[1:]) / 2.0
    return levels, thresholds


def pact_forward(x: jnp.ndarray, alpha: jnp.ndarray, bits: int) -> jnp.ndarray:
    """PACT [9]: y = clip(x, 0, alpha) quantized to 2^bits uniform levels.

    Gradients: STE inside [0, alpha]; d/dalpha = 1 where x > alpha (the
    published PACT gradient).
    """
    n = (1 << bits) - 1
    xc = jnp.clip(x, 0.0, alpha)
    step = alpha / n
    q = _round_ste(xc / step) * step
    return q


def pact_levels(alpha: float, bits: int) -> tuple[np.ndarray, np.ndarray]:
    n = (1 << bits) - 1
    levels = np.arange(1 << bits) * (alpha / n)
    thresholds = (levels[:-1] + levels[1:]) / 2.0
    return levels, thresholds


def quantize_codes_np(x: np.ndarray, thresholds: np.ndarray) -> np.ndarray:
    """NumPy code assignment (value -> code), matching the Rust
    ``Quantizer::code_of`` contract: code = #thresholds <= v."""
    return np.searchsorted(thresholds, x, side="right")


def export_quantizer(kind: str, bits: int, **kw) -> dict:
    """Serialize a quantizer to the model.json dict format."""
    if kind == "sign":
        levels, thr = sign_levels()
        bits = 1
    elif kind == "signed_uniform":
        levels, thr = signed_uniform_levels(bits, kw["scale"])
    elif kind == "pact":
        levels, thr = pact_levels(kw["alpha"], bits)
    else:
        raise ValueError(f"unknown quantizer kind {kind!r}")
    return {
        "bits": int(bits),
        "levels": [float(v) for v in levels],
        "thresholds": [float(v) for v in thr],
    }


def apply_quant(
    x: jnp.ndarray, kind: str, bits: int, alpha: jnp.ndarray | None = None,
    scale: float = 1.0,
) -> jnp.ndarray:
    """Dispatch a quantizer forward by kind (training path)."""
    if kind == "sign":
        return sign_forward(x)
    if kind == "signed_uniform":
        return signed_uniform_forward(x, bits, scale)
    if kind == "pact":
        assert alpha is not None
        return pact_forward(x, alpha, bits)
    raise ValueError(f"unknown quantizer kind {kind!r}")


def dequant_value_np(codes: np.ndarray, levels: np.ndarray) -> np.ndarray:
    """Code -> value lookup (NumPy)."""
    return levels[codes]
