"""AOT build: train → export model.json + HLO text + dataset binaries.

This is the single Python entry point `make artifacts` runs; after it
finishes, Python is never needed again — the Rust binary loads
``artifacts/<arch>.hlo.txt`` via PJRT and ``artifacts/<arch>.model.json``
for logic synthesis.

Interchange is HLO **text**, not ``lowered.compiler_ir().serialize()``:
jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Usage (from ``python/``):

    python -m compile.aot --out-dir ../artifacts            # all archs
    python -m compile.aot --out-dir ../artifacts --arch jsc-s --steps 1500
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import data as data_mod
from compile import model as model_mod
from compile import train as train_mod

# Default training budget per arch (1-CPU environment; accuracy saturates
# well before these step counts on the synthetic task).
DEFAULT_STEPS = {"jsc-s": 3500, "jsc-m": 3500, "jsc-l": 2500}
BATCH_EXPORT = 64  # batch size baked into the exported HLO


def to_hlo_text(lowered) -> str:
    """Lower a jitted function to XLA HLO text via StableHLO."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def export_hlo(spec, params, masks, mean, std, path: str) -> None:
    """Lower the full inference function (standardize → quantized forward →
    output values) with the Pallas kernel on the MAC path."""
    mean_j = jnp.asarray(mean.astype(np.float32))
    std_j = jnp.asarray(std.astype(np.float32))
    masks_j = [jnp.asarray(m) for m in masks]

    def infer(x):
        xn = (x - mean_j) / std_j
        out = model_mod.forward(params, masks_j, xn, spec, use_kernel=True)
        return (out,)

    example = jax.ShapeDtypeStruct((BATCH_EXPORT, spec.input_features), jnp.float32)
    lowered = jax.jit(infer).lower(example)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)


def build_arch(arch: str, out_dir: str, steps: int, seed: int,
               quiet: bool = False) -> dict:
    """Train both activation variants, export model JSONs + HLO."""
    report = {"arch": arch}

    # Our model (per-layer activation selection).
    spec, params, masks, (mean, std), stats = train_mod.train(
        arch, steps=steps, seed=seed, uniform_act=False, quiet=quiet)
    exported = model_mod.export_model(spec, params, masks, mean, std)
    model_mod.save_model_json(os.path.join(out_dir, f"{arch}.model.json"), exported)
    export_hlo(spec, params, masks, mean, std,
               os.path.join(out_dir, f"{arch}.hlo.txt"))
    report["ours_acc"] = stats["final_test_acc"]
    report["loss_curve"] = stats["loss_curve"]

    # LogicNets-style baseline (uniform activations) — the accuracy
    # comparator for Table I.
    spec_b, params_b, masks_b, (mean_b, std_b), stats_b = train_mod.train(
        arch, steps=steps, seed=seed, uniform_act=True, quiet=quiet)
    exported_b = model_mod.export_model(spec_b, params_b, masks_b, mean_b, std_b)
    model_mod.save_model_json(
        os.path.join(out_dir, f"{arch}.logicnets.model.json"), exported_b)
    report["baseline_acc"] = stats_b["final_test_acc"]
    return report


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--arch", default=None, help="single arch (default: all)")
    p.add_argument("--steps", type=int, default=None)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--quiet", action="store_true")
    args = p.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)

    # Dataset binaries (shared by rust examples/benches). One draw, split —
    # the class mixture itself is seed-dependent, so train/test must come
    # from the SAME generate() call (train.py splits identically).
    xs, ys = data_mod.generate(40_000, seed=1234)
    data_mod.save(os.path.join(args.out_dir, "jsc_train.bin"), xs[:30_000], ys[:30_000])
    data_mod.save(os.path.join(args.out_dir, "jsc_test.bin"), xs[30_000:], ys[30_000:])
    x_tr = xs[:30_000]
    x_te = xs[30_000:]
    print(f"wrote datasets: {x_tr.shape[0]} train / {x_te.shape[0]} test")

    archs = [args.arch] if args.arch else sorted(model_mod.ARCHS)
    reports = []
    for arch in archs:
        steps = args.steps or DEFAULT_STEPS[arch]
        print(f"=== building {arch} ({steps} steps) ===")
        reports.append(build_arch(arch, args.out_dir, steps, args.seed,
                                  quiet=args.quiet))

    with open(os.path.join(args.out_dir, "training_report.json"), "w") as f:
        json.dump(reports, f, indent=2)
    for r in reports:
        print(f"{r['arch']}: ours {r['ours_acc'] * 100:.2f}% vs "
              f"uniform-act baseline {r['baseline_acc'] * 100:.2f}%")


if __name__ == "__main__":
    main()
