"""QAT + FCP training loop (L2, build path only — never at serving time).

Trains the JSC architectures with Adam (implemented in-tree; optax is not
available offline), straight-through quantized activations, and
fanin-constrained pruning on the gradual schedule (or ADMM with
``--fcp admm``). Exports ``artifacts/<arch>.model.json`` for the Rust flow
plus the ``<arch>.logicnets.model.json`` uniform-activation baseline
(Table I's accuracy comparison).

Usage (from ``python/``):

    python -m compile.train --arch jsc-s --steps 3000
    python -m compile.train --arch jsc-s --ablate-act     # A2 ablation
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as data_mod
from compile import model as model_mod
from compile import prune


class Adam:
    """Minimal Adam over a pytree (optax is unavailable offline)."""

    def __init__(self, lr: float = 3e-3, b1: float = 0.9, b2: float = 0.999,
                 eps: float = 1e-8):
        self.lr, self.b1, self.b2, self.eps = lr, b1, b2, eps

    def init(self, params):
        zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
        return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params):
        t = state["t"] + 1
        m = jax.tree_util.tree_map(
            lambda m_, g: self.b1 * m_ + (1 - self.b1) * g, state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: self.b2 * v_ + (1 - self.b2) * g * g, state["v"], grads)
        mhat_scale = 1.0 / (1 - self.b1 ** t.astype(jnp.float32))
        vhat_scale = 1.0 / (1 - self.b2 ** t.astype(jnp.float32))
        new_params = jax.tree_util.tree_map(
            lambda p, m_, v_: p - self.lr * (m_ * mhat_scale)
            / (jnp.sqrt(v_ * vhat_scale) + self.eps),
            params, m, v)
        return new_params, {"m": m, "v": v, "t": t}


def train(
    arch: str,
    steps: int = 3000,
    batch: int = 256,
    seed: int = 0,
    uniform_act: bool = False,
    fcp: str = "gradual",
    train_samples: int = 30_000,
    test_samples: int = 10_000,
    lr: float = 3e-3,
    log_every: int = 500,
    quiet: bool = False,
):
    """Train one architecture; returns (spec, params, masks, stats dict)."""
    spec = model_mod.make_spec(arch, uniform_act=uniform_act)
    xs, ys = data_mod.generate(train_samples + test_samples, seed=1234)
    x_train, y_train = xs[:train_samples], ys[:train_samples]
    x_test, y_test = xs[train_samples:], ys[train_samples:]
    mean, std = data_mod.standardize_stats(x_train)
    xn_train = ((x_train - mean) / std).astype(np.float32)
    xn_test = ((x_test - mean) / std).astype(np.float32)

    state = model_mod.init_params(spec, seed)
    params, masks = state["params"], state["masks"]
    opt = Adam(lr=lr)
    opt_state = opt.init(params)

    # FCP state.
    prune_begin, prune_end = int(steps * 0.25), int(steps * 0.7)
    admm = None
    if fcp == "admm":
        admm = [
            prune.AdmmPruner((l.out_width, l.in_width), l.fanin)
            for l in spec.layers
        ]

    @jax.jit
    def step_fn(params, opt_state, masks_j, xb, yb):
        loss, grads = jax.value_and_grad(model_mod.loss_fn)(
            params, masks_j, xb, yb, spec)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    @jax.jit
    def acc_fn(params, masks_j, x, y):
        pred = model_mod.predict(params, masks_j, x, spec)
        return jnp.mean((pred == y).astype(jnp.float32))

    rng = np.random.RandomState(seed)
    t0 = time.time()
    losses = []
    for step in range(steps):
        idx = rng.randint(0, train_samples, size=batch)
        xb = jnp.asarray(xn_train[idx])
        yb = jnp.asarray(y_train[idx].astype(np.int32))
        masks_j = [jnp.asarray(m) for m in masks]
        params, opt_state, loss = step_fn(params, opt_state, masks_j, xb, yb)
        losses.append(float(loss))

        # ---- FCP mask refresh ----
        if fcp == "gradual" and step % 50 == 0 and step >= prune_begin:
            for li, l in enumerate(spec.layers):
                k = prune.gradual_schedule(
                    step, prune_begin, prune_end, l.in_width, l.fanin)
                w = np.asarray(params["w"][li])
                masks[li] = prune.topk_row_mask(w, k).astype(np.float32)
        elif fcp == "admm" and step % 50 == 0:
            for li in range(len(spec.layers)):
                w = np.asarray(params["w"][li], dtype=np.float64)
                admm[li].update(w)
                # penalty gradient applied directly (simple splitting)
                g = admm[li].penalty_grad(w)
                params["w"][li] = params["w"][li] - jnp.asarray(
                    (0.1 * g).astype(np.float32))
            if step >= prune_end:
                for li in range(len(spec.layers)):
                    w = np.asarray(params["w"][li], dtype=np.float64)
                    masks[li] = admm[li].final_mask(w).astype(np.float32)

        if not quiet and (step % log_every == 0 or step == steps - 1):
            masks_j = [jnp.asarray(m) for m in masks]
            a = float(acc_fn(params, masks_j, jnp.asarray(xn_test),
                             jnp.asarray(y_test.astype(np.int32))))
            print(f"[{arch}] step {step:5d} loss {float(loss):.4f} "
                  f"test-acc {a * 100:.2f}%  ({time.time() - t0:.1f}s)")

    # Final hard projection: every mask row exactly ≤ fanin.
    for li, l in enumerate(spec.layers):
        w = np.asarray(params["w"][li])
        current = masks[li] > 0
        if current.sum(axis=1).max() > l.fanin:
            masks[li] = prune.topk_row_mask(
                np.where(current, w, 0.0), l.fanin).astype(np.float32)
        # zero pruned weights in the exported params for cleanliness
        params["w"][li] = params["w"][li] * jnp.asarray(masks[li])

    masks_j = [jnp.asarray(m) for m in masks]
    final_acc = float(acc_fn(params, masks_j, jnp.asarray(xn_test),
                             jnp.asarray(y_test.astype(np.int32))))
    stats = {
        "arch": arch,
        "uniform_act": uniform_act,
        "fcp": fcp,
        "steps": steps,
        "final_test_acc": final_acc,
        "loss_curve": losses[:: max(1, steps // 200)],
        "train_seconds": time.time() - t0,
    }
    if not quiet:
        print(f"[{arch}] final test accuracy {final_acc * 100:.2f}% "
              f"(uniform_act={uniform_act}, fcp={fcp})")
    return spec, params, masks, (mean, std), stats


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="jsc-s", choices=sorted(model_mod.ARCHS))
    p.add_argument("--steps", type=int, default=3000)
    p.add_argument("--batch", type=int, default=256)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--fcp", default="gradual", choices=["gradual", "admm"])
    p.add_argument("--ablate-act", action="store_true",
                   help="A2 ablation: train both activation styles, report")
    p.add_argument("--out", default=None, help="model.json output path")
    args = p.parse_args()

    if args.ablate_act:
        results = {}
        for uniform in (False, True):
            *_, stats = train(args.arch, steps=args.steps, batch=args.batch,
                              seed=args.seed, uniform_act=uniform, fcp=args.fcp)
            results["uniform" if uniform else "per-layer"] = stats["final_test_acc"]
        print("\n=== A2: per-layer activation selection ablation ===")
        for k, v in results.items():
            print(f"  {k:>10}: {v * 100:.2f}%")
        print(f"  delta: {(results['per-layer'] - results['uniform']) * 100:+.2f}pp")
        return

    spec, params, masks, (mean, std), stats = train(
        args.arch, steps=args.steps, batch=args.batch, seed=args.seed,
        fcp=args.fcp)
    if args.out:
        exported = model_mod.export_model(spec, params, masks, mean, std)
        model_mod.save_model_json(args.out, exported)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
