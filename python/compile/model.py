"""L2 — JAX model definitions: the JSC MLPs with QAT and FCP.

The forward pass calls the L1 Pallas kernel (kernels.masked_dense) for every
layer's MAC block, applies the per-layer activation quantizer (quant.py),
and is what aot.py lowers to the HLO artifact. The training path uses the
same math through the reference implementation (kernels.ref) so JAX autodiff
plus STE gradients work untouched; pytest asserts both paths are bit-equal.

Architectures (DESIGN.md §5, LogicNets-derived, per the paper):

    JSC-S: 16 → 64 → 32 → 5,            β=2, γ=3  (6-bit neuron functions)
    JSC-M: 16 → 64 → 32 → 32 → 5,       β=2, γ=4  (8-bit)
    JSC-L: 16 → 32 → 64 → 192 → 192 → 16 → 5, β=3, γ=4  (12-bit)

Per-layer activation selection (the paper's key QAT idea): the input is
standardized (signed) → signed uniform quantizer; hidden layers are
non-negative → PACT with learned α; the output layer uses a wider signed
uniform quantizer feeding the off-chip argmax.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from compile import quant
from compile.kernels.masked_dense import masked_dense
from compile.kernels.ref import masked_dense_ref

# alpha_init values selected on held-out validation (see EXPERIMENTS.md A2).
ARCHS: dict[str, dict[str, Any]] = {
    "jsc-s": {"widths": [64, 32, 5], "act_bits": 2, "fanin": 3, "alpha_init": 0.5},
    "jsc-m": {"widths": [64, 32, 32, 5], "act_bits": 2, "fanin": 4, "alpha_init": 0.3},
    "jsc-l": {"widths": [32, 64, 192, 192, 16, 5], "act_bits": 3, "fanin": 4,
              "alpha_init": 0.5},
}


@dataclasses.dataclass
class LayerSpec:
    """Static layer description."""

    in_width: int
    out_width: int
    fanin: int
    act_kind: str  # "pact" | "signed_uniform"
    act_bits: int
    act_scale: float = 1.0  # for signed_uniform


@dataclasses.dataclass
class ModelSpec:
    """Static model description."""

    name: str
    input_features: int
    num_classes: int
    input_bits: int
    input_scale: float
    layers: list[LayerSpec]
    alpha_init: float = 2.0


def make_spec(arch: str, uniform_act: bool = False) -> ModelSpec:
    """Build the spec for a named architecture.

    ``uniform_act=True`` is the LogicNets-style ablation: signed uniform
    quantizers everywhere instead of per-layer selection (used to train the
    baseline models whose accuracy Table I's (+Inc.) column is measured
    against).
    """
    cfg = ARCHS[arch]
    widths, act_bits, fanin = cfg["widths"], cfg["act_bits"], cfg["fanin"]
    layers = []
    in_w = 16
    for li, out_w in enumerate(widths):
        last = li == len(widths) - 1
        if last:
            # Wider signed output quantizer feeding argmax.
            layers.append(
                LayerSpec(in_w, out_w, fanin, "signed_uniform", act_bits + 2, 0.25)
            )
        elif uniform_act:
            layers.append(LayerSpec(in_w, out_w, fanin, "signed_uniform", act_bits, 0.5))
        else:
            layers.append(LayerSpec(in_w, out_w, fanin, "pact", act_bits))
        in_w = out_w
    return ModelSpec(
        name=arch,
        input_features=16,
        num_classes=5,
        input_bits=act_bits,
        input_scale=1.0,
        layers=layers,
        alpha_init=cfg.get("alpha_init", 2.0),
    )


def init_params(spec: ModelSpec, seed: int) -> dict:
    """He-style init; masks start full; PACT α starts at 2.0."""
    rng = np.random.RandomState(seed)
    params = {"w": [], "b": [], "alpha": []}
    masks = []
    for l in spec.layers:
        std = float(np.sqrt(2.0 / l.in_width))
        params["w"].append(jnp.array(rng.randn(l.out_width, l.in_width) * std,
                                     dtype=jnp.float32))
        params["b"].append(jnp.zeros((l.out_width,), dtype=jnp.float32))
        params["alpha"].append(jnp.array(spec.alpha_init, dtype=jnp.float32))
        masks.append(np.ones((l.out_width, l.in_width), dtype=np.float32))
    return {"params": params, "masks": masks}


def input_quant_forward(x: jnp.ndarray, spec: ModelSpec) -> jnp.ndarray:
    """Quantize standardized features (training fake-quant path)."""
    return quant.signed_uniform_forward(x, spec.input_bits, spec.input_scale)


def forward(
    params: dict,
    masks: list[np.ndarray],
    x: jnp.ndarray,
    spec: ModelSpec,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """Quantized forward pass. `use_kernel=True` routes MACs through the
    Pallas kernel (export/inference path); False uses the autodiff-friendly
    reference (training path). Both are bit-equal (pytest enforced)."""
    h = input_quant_forward(x, spec)
    for li, l in enumerate(spec.layers):
        w = params["w"][li]
        b = params["b"][li]
        m = jnp.asarray(masks[li])
        if use_kernel:
            pre = masked_dense(h, w, m, b)
        else:
            pre = masked_dense_ref(h, w, m, b)
        h = quant.apply_quant(
            pre, l.act_kind, l.act_bits,
            alpha=params["alpha"][li], scale=l.act_scale,
        )
    return h


def predict(params: dict, masks: list[np.ndarray], x: jnp.ndarray,
            spec: ModelSpec, use_kernel: bool = False) -> jnp.ndarray:
    """Class predictions (argmax over quantized outputs)."""
    out = forward(params, masks, x, spec, use_kernel=use_kernel)
    return jnp.argmax(out[:, : spec.num_classes], axis=1)


def loss_fn(params: dict, masks: list[np.ndarray], x: jnp.ndarray,
            y: jnp.ndarray, spec: ModelSpec) -> jnp.ndarray:
    """Cross entropy over the (quantized) output values. The output
    quantizer's STE keeps this differentiable."""
    out = forward(params, masks, x, spec)
    logits = out[:, : spec.num_classes] * 8.0  # temperature for coarse codes
    logp = jax.nn.log_softmax(logits, axis=1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


# ---------------------------------------------------------------------------
# Export to the Rust interchange format (model.json)
# ---------------------------------------------------------------------------

def export_model(
    spec: ModelSpec,
    params: dict,
    masks: list[np.ndarray],
    feature_mean: np.ndarray,
    feature_std: np.ndarray,
) -> dict:
    """Serialize the trained model to the Rust flow's JSON schema.

    Weights are exported masked (only surviving fanin entries, aligned with
    the index list); quantizers as levels/thresholds tables.
    """
    layers = []
    for li, l in enumerate(spec.layers):
        w = np.asarray(params["w"][li], dtype=np.float64)
        b = np.asarray(params["b"][li], dtype=np.float64)
        m = masks[li] > 0
        mask_idx = [sorted(np.nonzero(m[n])[0].tolist()) for n in range(l.out_width)]
        weights = [[float(w[n, i]) for i in mask_idx[n]] for n in range(l.out_width)]
        if l.act_kind == "pact":
            act = quant.export_quantizer(
                "pact", l.act_bits, alpha=float(params["alpha"][li])
            )
        else:
            act = quant.export_quantizer(
                "signed_uniform", l.act_bits, scale=l.act_scale
            )
        layers.append(
            {
                "in": l.in_width,
                "out": l.out_width,
                "mask": mask_idx,
                "weights": weights,
                "bias": [float(v) for v in b],
                "act": act,
            }
        )
    return {
        "name": spec.name,
        "input_features": spec.input_features,
        "num_classes": spec.num_classes,
        "feature_mean": [float(v) for v in feature_mean],
        "feature_std": [float(v) for v in feature_std],
        "input_quant": quant.export_quantizer(
            "signed_uniform", spec.input_bits, scale=spec.input_scale
        ),
        "layers": layers,
    }


def save_model_json(path: str, exported: dict) -> None:
    """Write the interchange JSON."""
    with open(path, "w") as f:
        json.dump(exported, f)
