"""Synthetic jet-substructure-classification dataset (L2 build path).

The real JSC dataset [37] (16 high-level jet features, 5 classes: g/q/W/Z/t)
is an online OpenML download, unavailable in this offline environment;
DESIGN.md §4 records the substitution. This generator reproduces the task's
*shape*: a 5-class Gaussian mixture in a 6-dimensional latent space, mixed
into 16 correlated observables with physics-flavoured nonlinear warps
(saturating correlations, heavy-tailed masses) and observation noise, tuned
so a small float MLP lands at ≈75% accuracy — the band where the real JSC
architectures operate and where the QAT-vs-accuracy trade-offs of Table I
are meaningful.

The binary format written here is parsed by ``rust/src/data/dataset.rs``:

    magic "NNTD" | u32 version=1 | u32 samples | u32 features | u32 classes
    f32 features (row major) | u8 labels
"""

from __future__ import annotations

import struct

import numpy as np

NUM_FEATURES = 16
NUM_CLASSES = 5
MAGIC = b"NNTD"
VERSION = 1


def generate(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Return (features [n,16] f32, labels [n] u8), deterministic in seed."""
    rng = np.random.RandomState(seed)
    latent_dim = 6
    class_means = rng.randn(NUM_CLASSES, latent_dim) * 1.6
    mix = rng.randn(NUM_FEATURES, latent_dim) * 0.8
    scales = 0.6 + 0.8 * rng.rand(NUM_CLASSES, latent_dim)

    ys = rng.randint(0, NUM_CLASSES, size=n)
    z = class_means[ys] + scales[ys] * rng.randn(n, latent_dim)
    lin = z @ mix.T  # [n, 16]

    x = np.empty_like(lin)
    for i in range(NUM_FEATURES):
        col = lin[:, i]
        if i % 4 == 0:
            x[:, i] = col
        elif i % 4 == 1:
            x[:, i] = np.tanh(col) * 2.0
        elif i % 4 == 2:
            x[:, i] = np.log(np.abs(col) + 0.1)
        else:
            x[:, i] = col + 0.3 * col * col * np.sign(col) * 0.1
    x += 0.35 * rng.randn(n, NUM_FEATURES)
    return x.astype(np.float32), ys.astype(np.uint8)


def save(path: str, x: np.ndarray, y: np.ndarray, num_classes: int = NUM_CLASSES) -> None:
    """Write the NNTD binary format."""
    n, f = x.shape
    assert y.shape == (n,)
    with open(path, "wb") as fh:
        fh.write(MAGIC)
        fh.write(struct.pack("<IIII", VERSION, n, f, num_classes))
        fh.write(x.astype("<f4").tobytes())
        fh.write(y.astype(np.uint8).tobytes())


def load(path: str) -> tuple[np.ndarray, np.ndarray, int]:
    """Read the NNTD binary format -> (x, y, num_classes)."""
    with open(path, "rb") as fh:
        buf = fh.read()
    assert buf[:4] == MAGIC, "bad magic"
    version, n, f, c = struct.unpack_from("<IIII", buf, 4)
    assert version == VERSION, f"unsupported version {version}"
    off = 20
    x = np.frombuffer(buf, dtype="<f4", count=n * f, offset=off).reshape(n, f)
    off += n * f * 4
    y = np.frombuffer(buf, dtype=np.uint8, count=n, offset=off)
    return x.copy(), y.copy(), c


def standardize_stats(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-feature mean/std (std floored) — must match the Rust contract."""
    mean = x.mean(axis=0)
    std = np.maximum(x.std(axis=0), 1e-9)
    return mean, std
